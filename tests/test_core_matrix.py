"""Configuration matrix + hashing: unit and property tests."""
import itertools

import pytest
from _compat import given, settings, st

from repro.core import ConfigMatrix, ConfigMatrixError, HashingError
from repro.core.hashing import canonicalize, stable_hash, task_key


def model_a():
    return "a"


def model_b():
    return "b"


class TestExpansion:
    def test_paper_example_counts(self):
        # 3 x 2 x 3 x 3 = 54 tasks, exactly the paper's example.
        m = ConfigMatrix.from_dict(
            {
                "parameters": {
                    "dataset": ["digits", "wine", "cancer"],
                    "feature_engineering": ["dummy", "simple"],
                    "preprocessing": ["none", "minmax", "standard"],
                    "model": [model_a, model_b, "svc"],
                },
                "settings": {"n_fold": 5},
            }
        )
        assert m.cartesian_size == 54
        tasks = m.task_list()
        assert len(tasks) == 54
        assert all(t.settings == {"n_fold": 5} for t in tasks)

    def test_exclude_is_partial_match_lookup(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"a": [1, 2, 3], "b": ["x", "y"]},
                "exclude": [{"a": 2}],  # kills every combo with a=2
            }
        )
        combos = list(m.combinations())
        assert len(combos) == 4
        assert all(c["a"] != 2 for c in combos)

    def test_exclude_full_assignment(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"a": [1, 2], "b": ["x", "y"]},
                "exclude": [{"a": 1, "b": "y"}],
            }
        )
        combos = list(m.combinations())
        assert {"a": 1, "b": "y"} not in combos
        assert len(combos) == 3

    def test_exclude_matches_callables(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"model": [model_a, model_b]},
                "exclude": [{"model": model_a}],
            }
        )
        assert [c["model"] for c in m.combinations()] == [model_b]

    def test_task_indices_stable_and_keys_unique(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": [1, 2], "b": [3, 4]}})
        tasks = m.task_list()
        assert [t.index for t in tasks] == [0, 1, 2, 3]
        assert len({t.key for t in tasks}) == 4

    def test_shard_partition(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": list(range(10))}})
        parts = [m.shard(i, 3) for i in range(3)]
        all_idx = sorted(t.index for p in parts for t in p)
        assert all_idx == list(range(10))

    @pytest.mark.parametrize(
        "bad",
        [
            {},
            {"parameters": {}},
            {"parameters": {"a": []}},
            {"parameters": {"a": [1]}, "bogus": 1},
            {"parameters": {"a": [1]}, "exclude": [{"zzz": 1}]},
            {"parameters": {"a": "not-a-list"}},
        ],
    )
    def test_invalid_matrices_rejected(self, bad):
        with pytest.raises(ConfigMatrixError):
            ConfigMatrix.from_dict(bad)

    @given(
        sizes=st.lists(st.integers(min_value=1, max_value=4), min_size=1, max_size=4),
        n_excl=st.integers(min_value=0, max_value=5),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_property_count_equals_product_minus_excluded(self, sizes, n_excl, seed):
        import random

        rng = random.Random(seed)
        params = {f"p{i}": list(range(n)) for i, n in enumerate(sizes)}
        full = list(itertools.product(*params.values()))
        names = list(params.keys())
        excl = []
        for _ in range(n_excl):
            combo = rng.choice(full)
            keys = rng.sample(names, rng.randint(1, len(names)))
            excl.append({k: combo[names.index(k)] for k in keys})
        m = ConfigMatrix.from_dict({"parameters": params, "exclude": excl})
        expected = [
            c
            for c in full
            if not any(
                all(c[names.index(k)] == v for k, v in rule.items()) for rule in excl
            )
        ]
        assert len(list(m.combinations())) == len(expected)


class TestAlgebra:
    """The v2 compositional matrix API: + * where derive."""

    def _keys(self, m):
        return [t.key for t in m.tasks()]

    def test_chain_concatenates_and_dedups(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1, 2]}})
        m2 = ConfigMatrix.from_dict({"parameters": {"a": [2, 3]}})
        chained = m1 + m2
        params = [t.params for t in chained.tasks()]
        assert params == [{"a": 1}, {"a": 2}, {"a": 3}]  # a=2 de-duped by key
        assert len(m1 + m1) == len(m1)

    def test_chain_accepts_paper_dicts_and_flattens(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1]}})
        c = m1 + {"parameters": {"a": [2]}} + {"parameters": {"a": [3]}}
        assert [t.params["a"] for t in c.tasks()] == [1, 2, 3]
        assert len(c.parts) == 3  # flattened, not nested chains

    def test_chain_keeps_distinct_settings_distinct(self):
        # Identical params under different settings are different tasks.
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1]}, "settings": {"s": 1}})
        m2 = ConfigMatrix.from_dict({"parameters": {"a": [1]}, "settings": {"s": 2}})
        assert len(m1 + m2) == 2

    def test_product_matches_single_matrix(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1, 2], "b": ["x", "y"]}})
        m2 = ConfigMatrix.from_dict({"parameters": {"c": [True, False]}})
        combined = ConfigMatrix.from_dict(
            {"parameters": {"a": [1, 2], "b": ["x", "y"], "c": [True, False]}}
        )
        assert set(self._keys(m1 * m2)) == set(self._keys(combined))

    def test_product_rejects_overlapping_axes(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1]}})
        with pytest.raises(ConfigMatrixError):
            m1 * {"parameters": {"a": [2]}}

    def test_product_merges_settings_and_rejects_conflicts(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1]}, "settings": {"s": 1}})
        m2 = ConfigMatrix.from_dict({"parameters": {"b": [2]}, "settings": {"t": 2}})
        (task,) = (m1 * m2).tasks()
        assert task.settings == {"s": 1, "t": 2}
        bad = ConfigMatrix.from_dict({"parameters": {"c": [3]}, "settings": {"s": 9}})
        with pytest.raises(ConfigMatrixError):
            list((m1 * bad).tasks())

    def test_where_equivalent_to_dict_exclude(self):
        base = {"parameters": {"a": [1, 2, 3], "b": ["x", "y"]}}
        excluded = ConfigMatrix.from_dict({**base, "exclude": [{"a": 2}]})
        filtered = ConfigMatrix.from_dict(base).where(lambda p: p["a"] != 2)
        assert self._keys(filtered) == self._keys(excluded)

    def test_derive_adds_param_and_changes_key(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": [1, 2]}})
        d = m.derive("a_sq", lambda p: p["a"] ** 2)
        tasks = list(d.tasks())
        assert [t.params for t in tasks] == [{"a": 1, "a_sq": 1}, {"a": 2, "a_sq": 4}]
        assert set(self._keys(d)).isdisjoint(self._keys(m))
        assert d.axis_names == ["a", "a_sq"]
        # Deriving with a different function produces different identities.
        d2 = m.derive("a_sq", lambda p: p["a"] ** 3)
        assert self._keys(d)[1] != self._keys(d2)[1]

    def test_derive_rejects_axis_collision(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": [1]}})
        with pytest.raises(ConfigMatrixError):
            m.derive("a", lambda p: 0)

    def test_operators_compose(self):
        m = (
            ConfigMatrix.from_dict({"parameters": {"a": [1, 2, 3]}})
            * {"parameters": {"b": [10, 20]}}
        ).where(lambda p: p["a"] != 2).derive("ab", lambda p: p["a"] * p["b"])
        tasks = m.task_list()
        assert len(tasks) == 4
        assert all(t.params["ab"] == t.params["a"] * t.params["b"] for t in tasks)
        assert [t.index for t in tasks] == [0, 1, 2, 3]

    def test_key_stability_across_constructions(self):
        build = lambda: (
            ConfigMatrix.from_dict(
                {"parameters": {"a": [1, 2]}, "settings": {"s": 5}}
            )
            * {"parameters": {"b": ["x"]}}
        ).derive("twice", _twice)
        assert self._keys(build()) == self._keys(build())

    @given(
        width_a=st.integers(min_value=1, max_value=4),
        width_b=st.integers(min_value=1, max_value=4),
        cut=st.integers(min_value=0, max_value=3),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_product_then_where_counts(self, width_a, width_b, cut):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": list(range(width_a))}})
        m2 = ConfigMatrix.from_dict({"parameters": {"b": list(range(width_b))}})
        prod = m1 * m2
        assert len(prod) == width_a * width_b
        kept = prod.where(lambda p: p["a"] != cut)
        expected = (width_a - (1 if cut < width_a else 0)) * width_b
        if expected == 0:
            with pytest.raises(ConfigMatrixError):
                kept.task_list()
        else:
            assert len(kept.task_list()) == expected
            # where() must agree with the paper's dict exclude.
            dict_form = ConfigMatrix.from_dict(
                {
                    "parameters": {"a": list(range(width_a)), "b": list(range(width_b))},
                    "exclude": [{"a": cut}] if cut < width_a else [],
                }
            )
            assert {t.key for t in kept.tasks()} == {t.key for t in dict_form.tasks()}

    @given(
        values=st.lists(st.integers(min_value=0, max_value=6), min_size=1, max_size=8),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_chain_self_union_idempotent(self, values):
        m = ConfigMatrix.from_dict({"parameters": {"a": values}})
        expect = len(set(values))
        assert len(m + m) == expect
        assert len((m + m) + m) == expect


def _twice(p):
    return p["a"] * 2


class TestSettingsInKey:
    """Satellite: settings (and namespace) are part of task identity."""

    def test_same_params_different_settings_different_key(self):
        m1 = ConfigMatrix.from_dict({"parameters": {"a": [1]}, "settings": {"s": 1}})
        m2 = ConfigMatrix.from_dict({"parameters": {"a": [1]}, "settings": {"s": 2}})
        (t1,), (t2,) = m1.task_list(), m2.task_list()
        assert t1.params == t2.params
        assert t1.key != t2.key

    def test_namespace_changes_key(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": [1]}})
        (plain,) = m.task_list()
        (ns,) = m.task_list(namespace="serve")
        assert plain.key != ns.key
        assert m.task_list(namespace="serve")[0].key == ns.key

    def test_task_key_function_folds_settings(self):
        assert task_key({"a": 1}) == task_key({"a": 1}, settings={})
        assert task_key({"a": 1}) != task_key({"a": 1}, settings={"s": 1})
        assert task_key({"a": 1}, namespace="x") != task_key({"a": 1}, namespace="y")


class TestHashing:
    def test_dict_order_invariance(self):
        assert stable_hash({"a": 1, "b": 2}) == stable_hash({"b": 2, "a": 1})

    def test_nested_structures(self):
        v1 = {"x": [1, 2, {"y": (3, 4)}], "s": {2, 1}}
        v2 = {"s": {1, 2}, "x": [1, 2, {"y": [3, 4]}]}  # tuple/list normalise
        assert stable_hash(v1) == stable_hash(v2)

    def test_callables_by_qualified_name(self):
        assert stable_hash(model_a) != stable_hash(model_b)
        assert stable_hash(model_a) == stable_hash(model_a)

    def test_lambda_rejected(self):
        with pytest.raises(HashingError):
            stable_hash(lambda x: x)

    def test_closure_rejected(self):
        def outer():
            def inner():
                return 1

            return inner

        with pytest.raises(HashingError):
            stable_hash(outer())

    def test_dataclass_and_model_config(self):
        from repro.configs.registry import get_config

        c1 = get_config("qwen3-8b")
        c2 = get_config("qwen3-8b")
        assert stable_hash(c1) == stable_hash(c2)
        assert stable_hash(c1) != stable_hash(get_config("llama3.2-3b"))

    def test_numpy_values(self):
        import numpy as np

        a = np.arange(6).reshape(2, 3)
        assert stable_hash(a) == stable_hash(a.copy())
        assert stable_hash(a) != stable_hash(a.T)
        assert stable_hash(np.float32(1.5)) == stable_hash(1.5)

    def test_float_specials(self):
        assert stable_hash(float("nan")) == stable_hash(float("nan"))
        assert stable_hash(float("inf")) != stable_hash(float("-inf"))

    @given(st.dictionaries(st.text(min_size=1, max_size=8), st.integers(), min_size=1, max_size=6))
    @settings(max_examples=30, deadline=None)
    def test_property_shuffled_dict_same_key(self, d):
        items = list(d.items())
        assert task_key(dict(items)) == task_key(dict(reversed(items)))


class TestTaskViews:
    """shard()/subset() return lazy MatrixBase views that keep composing."""

    def _m(self, n=10):
        return ConfigMatrix.from_dict({"parameters": {"a": list(range(n))}})

    def test_views_are_matrices_and_iterate_like_lists(self):
        from repro.core import MatrixBase, TaskViewMatrix

        view = self._m().shard(0, 3)
        assert isinstance(view, MatrixBase) and isinstance(view, TaskViewMatrix)
        # list behavior via iteration / .tasks(): base indices are preserved
        assert [t.index for t in view] == [0, 3, 6, 9]
        assert [t.index for t in view.tasks()] == [0, 3, 6, 9]
        assert len(view) == 4

    def test_shard_keys_match_full_matrix(self):
        m = self._m()
        full = {t.index: t.key for t in m.task_list()}
        for i in range(3):
            for t in m.shard(i, 3):
                assert t.key == full[t.index], "sharding must not rekey tasks"

    def test_subset_chains_with_algebra(self):
        m = self._m(6)
        other = ConfigMatrix.from_dict({"parameters": {"b": [0, 1]}})
        comp = (m.subset(lambda p: p["a"] % 2 == 0) * other).where(
            lambda p: p["a"] + p["b"] < 5
        )
        combos = sorted((t.params["a"], t.params["b"]) for t in comp.tasks())
        assert combos == [(0, 0), (0, 1), (2, 0), (2, 1), (4, 0)]

    def test_shard_union_roundtrips(self):
        m = self._m(7)
        union = m.shard(0, 2) + m.shard(1, 2)
        assert sorted(t.params["a"] for t in union.tasks()) == list(range(7))
        # de-dup by key: overlapping shards collapse
        overlap = m.shard(0, 2) + m.shard(0, 2)
        assert len(overlap.task_list()) == 4
