"""Configuration matrix + hashing: unit and property tests."""
import itertools

import pytest
from _compat import given, settings, st

from repro.core import ConfigMatrix, ConfigMatrixError, HashingError
from repro.core.hashing import canonicalize, stable_hash, task_key


def model_a():
    return "a"


def model_b():
    return "b"


class TestExpansion:
    def test_paper_example_counts(self):
        # 3 x 2 x 3 x 3 = 54 tasks, exactly the paper's example.
        m = ConfigMatrix.from_dict(
            {
                "parameters": {
                    "dataset": ["digits", "wine", "cancer"],
                    "feature_engineering": ["dummy", "simple"],
                    "preprocessing": ["none", "minmax", "standard"],
                    "model": [model_a, model_b, "svc"],
                },
                "settings": {"n_fold": 5},
            }
        )
        assert m.cartesian_size == 54
        tasks = m.task_list()
        assert len(tasks) == 54
        assert all(t.settings == {"n_fold": 5} for t in tasks)

    def test_exclude_is_partial_match_lookup(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"a": [1, 2, 3], "b": ["x", "y"]},
                "exclude": [{"a": 2}],  # kills every combo with a=2
            }
        )
        combos = list(m.combinations())
        assert len(combos) == 4
        assert all(c["a"] != 2 for c in combos)

    def test_exclude_full_assignment(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"a": [1, 2], "b": ["x", "y"]},
                "exclude": [{"a": 1, "b": "y"}],
            }
        )
        combos = list(m.combinations())
        assert {"a": 1, "b": "y"} not in combos
        assert len(combos) == 3

    def test_exclude_matches_callables(self):
        m = ConfigMatrix.from_dict(
            {
                "parameters": {"model": [model_a, model_b]},
                "exclude": [{"model": model_a}],
            }
        )
        assert [c["model"] for c in m.combinations()] == [model_b]

    def test_task_indices_stable_and_keys_unique(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": [1, 2], "b": [3, 4]}})
        tasks = m.task_list()
        assert [t.index for t in tasks] == [0, 1, 2, 3]
        assert len({t.key for t in tasks}) == 4

    def test_shard_partition(self):
        m = ConfigMatrix.from_dict({"parameters": {"a": list(range(10))}})
        parts = [m.shard(i, 3) for i in range(3)]
        all_idx = sorted(t.index for p in parts for t in p)
        assert all_idx == list(range(10))

    @pytest.mark.parametrize(
        "bad",
        [
            {},
            {"parameters": {}},
            {"parameters": {"a": []}},
            {"parameters": {"a": [1]}, "bogus": 1},
            {"parameters": {"a": [1]}, "exclude": [{"zzz": 1}]},
            {"parameters": {"a": "not-a-list"}},
        ],
    )
    def test_invalid_matrices_rejected(self, bad):
        with pytest.raises(ConfigMatrixError):
            ConfigMatrix.from_dict(bad)

    @given(
        sizes=st.lists(st.integers(min_value=1, max_value=4), min_size=1, max_size=4),
        n_excl=st.integers(min_value=0, max_value=5),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=40, deadline=None)
    def test_property_count_equals_product_minus_excluded(self, sizes, n_excl, seed):
        import random

        rng = random.Random(seed)
        params = {f"p{i}": list(range(n)) for i, n in enumerate(sizes)}
        full = list(itertools.product(*params.values()))
        names = list(params.keys())
        excl = []
        for _ in range(n_excl):
            combo = rng.choice(full)
            keys = rng.sample(names, rng.randint(1, len(names)))
            excl.append({k: combo[names.index(k)] for k in keys})
        m = ConfigMatrix.from_dict({"parameters": params, "exclude": excl})
        expected = [
            c
            for c in full
            if not any(
                all(c[names.index(k)] == v for k, v in rule.items()) for rule in excl
            )
        ]
        assert len(list(m.combinations())) == len(expected)


class TestHashing:
    def test_dict_order_invariance(self):
        assert stable_hash({"a": 1, "b": 2}) == stable_hash({"b": 2, "a": 1})

    def test_nested_structures(self):
        v1 = {"x": [1, 2, {"y": (3, 4)}], "s": {2, 1}}
        v2 = {"s": {1, 2}, "x": [1, 2, {"y": [3, 4]}]}  # tuple/list normalise
        assert stable_hash(v1) == stable_hash(v2)

    def test_callables_by_qualified_name(self):
        assert stable_hash(model_a) != stable_hash(model_b)
        assert stable_hash(model_a) == stable_hash(model_a)

    def test_lambda_rejected(self):
        with pytest.raises(HashingError):
            stable_hash(lambda x: x)

    def test_closure_rejected(self):
        def outer():
            def inner():
                return 1

            return inner

        with pytest.raises(HashingError):
            stable_hash(outer())

    def test_dataclass_and_model_config(self):
        from repro.configs.registry import get_config

        c1 = get_config("qwen3-8b")
        c2 = get_config("qwen3-8b")
        assert stable_hash(c1) == stable_hash(c2)
        assert stable_hash(c1) != stable_hash(get_config("llama3.2-3b"))

    def test_numpy_values(self):
        import numpy as np

        a = np.arange(6).reshape(2, 3)
        assert stable_hash(a) == stable_hash(a.copy())
        assert stable_hash(a) != stable_hash(a.T)
        assert stable_hash(np.float32(1.5)) == stable_hash(1.5)

    def test_float_specials(self):
        assert stable_hash(float("nan")) == stable_hash(float("nan"))
        assert stable_hash(float("inf")) != stable_hash(float("-inf"))

    @given(st.dictionaries(st.text(min_size=1, max_size=8), st.integers(), min_size=1, max_size=6))
    @settings(max_examples=30, deadline=None)
    def test_property_shuffled_dict_same_key(self, d):
        items = list(d.items())
        assert task_key(dict(items)) == task_key(dict(reversed(items)))
