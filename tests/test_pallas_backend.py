"""The Pallas flash-attention kernel as the model's attention backend must
reproduce the XLA path end-to-end (logits + gradients)."""
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.sharding.rules import ShardingCtx


def _batch(cfg, B=2, S=128):
    key = jax.random.PRNGKey(1)
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }


def test_pallas_attention_backend_matches_xla():
    base = get_config("llama3.2-3b").reduced()
    sctx = ShardingCtx.null()
    params = init_params(lm.model_schema(base), jax.random.PRNGKey(0))
    batch = _batch(base)

    cfg_x = replace(base, attn_backend="xla")
    cfg_p = replace(base, attn_backend="pallas")
    loss_x, _ = jax.jit(lambda p, b: lm.forward_train(p, cfg_x, b, sctx))(params, batch)
    loss_p, _ = jax.jit(lambda p, b: lm.forward_train(p, cfg_p, b, sctx))(params, batch)
    assert abs(float(loss_x) - float(loss_p)) < 2e-3, (loss_x, loss_p)

    gx = jax.grad(lambda p: lm.forward_train(p, cfg_x, batch, sctx)[0])(params)
    gp = jax.grad(lambda p: lm.forward_train(p, cfg_p, batch, sctx)[0])(params)
    for lx, lp in zip(jax.tree.leaves(gx), jax.tree.leaves(gp)):
        scale = float(jnp.max(jnp.abs(lx))) + 1e-6
        assert float(jnp.max(jnp.abs(lx - lp))) / scale < 5e-2


def test_pallas_backend_windowed_arch():
    base = get_config("recurrentgemma-2b").reduced()
    sctx = ShardingCtx.null()
    params = init_params(lm.model_schema(base), jax.random.PRNGKey(0))
    batch = _batch(base, S=64)
    cfg_p = replace(base, attn_backend="pallas")
    loss_x, _ = jax.jit(lambda p, b: lm.forward_train(p, base, b, sctx))(params, batch)
    loss_p, _ = jax.jit(lambda p, b: lm.forward_train(p, cfg_p, b, sctx))(params, batch)
    assert abs(float(loss_x) - float(loss_p)) < 2e-3
