"""Sharded multi-device serving: tensor-parallel stepping over a
("data", "model") mesh must be a pure layout change.

Greedy token identity sharded vs unsharded across the model zoo's state
families, layout-aware hot-path features (CoW forks, preemption swap and
recompute round-trips, speculative rollback) with pool conservation under
a 2-device mesh, bounded compile counts independent of mesh size, the
analytic decode roofline predictor, and the policy-file regression gate.

Multi-device cases gate on ``mesh.devices_required(2)`` and *skip* on
1-device CI; the sharded-smoke CI lane forces 8 host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) so they run for
real there. Everything mesh-independent (predictor math, policy loading,
mesh error messages) runs everywhere.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.models.schema import count_params, init_params
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx

needs_2dev = pytest.mark.skipif(
    not mesh_mod.devices_required(2),
    reason="needs >=2 XLA devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

ARCHS = [
    "llama3.2-3b",  # dense GQA, paged
    "recurrentgemma-2b",  # windowed ring KV + RG-LRU hybrid
    "deepseek-v2-236b",  # MLA compressed cache (per-slot path)
    "xlstm-1.3b",  # pure recurrent (mLSTM + sLSTM), zero pages
    "llama4-scout-17b-a16e",  # MoE, scan-stacked groups
]


def _params_for(name):
    cfg = get_config(name).reduced()
    return cfg, init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32) for p in lengths]


def _run(cfg, params, prompts, max_new=6, **sched_kw):
    sched = Scheduler(
        cfg, params, ShardingCtx.null(), SchedulerConfig(**sched_kw)
    )
    for p in prompts:
        sched.submit(Request(prompt=p, max_new_tokens=max_new))
    outs = [rs.tokens for rs in sched.run()]
    return outs, sched


# ==========================================================================
# Token identity: sharded vs single-device, across state families
# ==========================================================================
class TestShardedTokenIdentity:
    @needs_2dev
    @pytest.mark.parametrize("arch", ARCHS)
    def test_sharded_greedy_matches_unsharded(self, arch):
        """The same workload on mesh (1, 2) must emit the same greedy
        tokens as the 1-device step, with identical trace counts: sharding
        changes array layouts, never the math or the compile cadence."""
        cfg, params = _params_for(arch)
        prompts = _prompts(cfg, (8, 21, 13))
        base, s0 = _run(
            cfg, params, prompts, cache_len=64, chunk_budget=16, page_size=8
        )
        shd, s1 = _run(
            cfg, params, prompts,
            cache_len=64, chunk_budget=16, page_size=8, mesh_shape=(1, 2),
        )
        assert base == shd
        assert s1.stats()["mesh"] == {"data": 1, "model": 2}
        assert s1.stats()["mesh_devices"] == 2
        assert (s0.decode_traces, s0.chunk_traces, s0.admit_traces) == (
            s1.decode_traces, s1.chunk_traces, s1.admit_traces,
        )

    @needs_2dev
    def test_sharded_state_actually_sharded(self):
        """The resolved layer shardings place at least one leaf over the
        model axis — the mesh isn't silently all-replicated."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, mesh_shape=(1, 2)),
        )
        assert sched._layer_shardings is not None
        specs = jax.tree.leaves(
            jax.tree.map(lambda s: str(s.spec), sched._layer_shardings)
        )
        assert any("model" in s for s in specs), specs
        b = sched.paged_cache_bytes()
        assert 0 < b["bytes_per_page_per_device"] < b["bytes_per_page"]


# ==========================================================================
# Layout-aware hot-path features under a 2-device mesh
# ==========================================================================
class TestShardedHotPaths:
    @needs_2dev
    @pytest.mark.parametrize("policy", ["swap", "recompute"])
    def test_preemption_roundtrip_identity_and_conservation(self, policy):
        """A pool sized to force preemption: preempted-then-resumed requests
        stay token-identical to the uncontended single-device run, and every
        page returns to the pool on drain."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, (16, 18, 17, 20))
        base, _ = _run(
            cfg, params, prompts, max_new=10,
            n_slots=3, cache_len=64, chunk_budget=16, page_size=4,
        )
        shd, sched = _run(
            cfg, params, prompts, max_new=10,
            n_slots=3, cache_len=64, chunk_budget=16, page_size=4,
            n_pages=14, preemption=policy, mesh_shape=(1, 2),
        )
        assert base == shd
        assert sched.preemptions_total > 0, "pool never ran dry; tighten it"
        assert sched.pool.in_use == 0
        assert sched.pool.available() == sched.pages.n_pages

    @needs_2dev
    def test_cow_fork_shard_map_under_mesh(self):
        """Prefix sharing + a second writer: the shard_map CoW program forks
        shared pages device-locally and the fork is observable (cow_traces)
        without breaking greedy identity."""
        cfg, params = _params_for("llama3.2-3b")
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
        prompts = [
            np.concatenate([shared, rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)])
            for t in (5, 9)
        ]
        kw = dict(
            n_slots=2, cache_len=64, chunk_budget=16, page_size=8,
            prefix_sharing=True,
        )
        base, s0 = _run(cfg, params, prompts, **kw)
        shd, s1 = _run(cfg, params, prompts, mesh_shape=(1, 2), **kw)
        assert base == shd
        assert s1.prefix_hits == s0.prefix_hits
        # Force a fork through the CoW program directly so the shard_map
        # copy itself is exercised even when the scheduler's write pattern
        # keeps steady-state CoW a no-op: real KV data lands in page 0
        # during the run, then page 0 is forked into page 1.
        from repro.models import blocks

        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(mesh_shape=(1, 2), **kw),
        )
        sched.submit(Request(prompt=prompts[0], max_new_tokens=2))
        sched.run()
        layers = sched._states["layers"]
        src = jax.numpy.asarray([0], jax.numpy.int32)
        dst = jax.numpy.asarray([1], jax.numpy.int32)
        forked = sched._cow_jit(layers, src, dst)
        assert sched.cow_traces >= 1
        caps = blocks.stack_paged_caps(cfg, kw["cache_len"])
        for cap, old, new in zip(
            jax.tree.leaves(caps),
            jax.tree.leaves(layers),
            jax.tree.leaves(forked),
        ):
            if not cap:
                continue
            old_np, new_np = np.asarray(old), np.asarray(new)
            if old_np.ndim == 5:
                np.testing.assert_array_equal(new_np[:, 1], old_np[:, 0])
            else:
                np.testing.assert_array_equal(new_np[1], old_np[0])

    @needs_2dev
    def test_speculative_rollback_identity_under_mesh(self):
        """Oracle-quality and garbage drafts: verify, partial-accept
        rollback (pos fixup for dense) and page truncation all run sharded
        and stay token-identical."""
        cfg, params = _params_for("llama3.2-3b")
        p = np.array([5, 6, 7, 8, 5, 6, 7, 8, 5, 6], np.int32)
        kw = dict(
            n_slots=2, cache_len=64, chunk_budget=16, page_size=8,
            speculative=True, draft_k=4,
        )
        base, s0 = _run(cfg, params, [p, p[1:]], max_new=8, **kw)
        shd, s1 = _run(cfg, params, [p, p[1:]], max_new=8, mesh_shape=(1, 2), **kw)
        assert base == shd
        assert s1.total_spec_steps == s0.total_spec_steps
        assert s1.verify_traces == s0.verify_traces
        assert s1.accepted_tokens_total == s0.accepted_tokens_total

    @needs_2dev
    def test_recurrent_replay_rollback_under_mesh(self):
        """Archs whose state advances through rejected tokens roll back by
        snapshot replay — sharded, that replay must also stay identical."""
        cfg, params = _params_for("recurrentgemma-2b")
        p = np.array([3, 9, 4, 3, 9, 4, 3, 9], np.int32)
        kw = dict(
            n_slots=2, cache_len=64, chunk_budget=16, page_size=8,
            speculative=True, draft_k=3,
        )
        base, s0 = _run(cfg, params, [p], max_new=7, **kw)
        shd, s1 = _run(cfg, params, [p], max_new=7, mesh_shape=(1, 2), **kw)
        assert base == shd
        assert s1.total_spec_replays == s0.total_spec_replays


# ==========================================================================
# Data-axis partitioning: slots and pool slices split across `data`
# ==========================================================================
class TestDataAxisPartitioning:
    @needs_2dev
    @pytest.mark.parametrize("arch", ARCHS)
    def test_data_axis_greedy_identity(self, arch):
        """mesh (2, 1): the slot batch and (for paged archs) the page pool
        partition across the data axis; greedy tokens and compile cadence
        stay identical to the single-device run."""
        cfg, params = _params_for(arch)
        prompts = _prompts(cfg, (8, 21, 13, 9))
        kw = dict(n_slots=4, cache_len=64, chunk_budget=16, page_size=8)
        base, s0 = _run(cfg, params, prompts, **kw)
        shd, s1 = _run(cfg, params, prompts, mesh_shape=(2, 1), **kw)
        assert base == shd
        assert s1.stats()["mesh"] == {"data": 2, "model": 1}
        assert (s0.decode_traces, s0.chunk_traces, s0.admit_traces) == (
            s1.decode_traces, s1.chunk_traces, s1.admit_traces,
        )

    @needs_2dev
    def test_data_axis_partitions_pool_and_slots(self):
        """With data=2 dividing n_slots and n_pages, the MemoryManager runs
        two per-shard sub-pools (each with its own trash row) and the live
        pool leaves are page-axis sharded over data — not replicated."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(
                n_slots=4, cache_len=64, chunk_budget=16, page_size=8,
                mesh_shape=(2, 1),
            ),
        )
        mem = sched.mem
        assert mem.data_shards == 2
        assert len(mem.pools) == 2
        assert all(p.layout.n_pages == mem.n_pages // 2 for p in mem.pools)
        # Slot -> shard follows the contiguous batch blocks; each shard's
        # trash row is the last row of its GSPMD block.
        assert [mem.shard_of(s) for s in range(4)] == [0, 0, 1, 1]
        per, stride = mem.n_pages // 2, mem.n_pages // 2 + 1
        assert mem.trash_of(0) == per
        assert mem.trash_of(3) == stride + per
        with pytest.raises(AttributeError):
            mem.pool  # single-pool view is unavailable when partitioned
        # The live device pool leaves carry a data-sharded page axis.
        total = sched.pages.total_pages
        page_leaves = [
            (arr.ndim, arr.sharding.spec)
            for arr in jax.tree.leaves(sched._states["layers"])
            if arr.ndim >= 4 and arr.shape[arr.ndim - 4] == total
        ]
        assert page_leaves, "no pool-shaped leaves found"
        for ndim, spec in page_leaves:
            # The page axis (4th from the end) carries the data axis.
            padded = tuple(spec) + (None,) * ndim
            assert padded[ndim - 4] in ("data", ("data",)), spec
        # Accounting reflects the partition.
        st = sched.stats()["pages"]
        assert st["data_shards"] == 2

    @needs_2dev
    def test_data_axis_falls_back_when_indivisible(self):
        """n_pages not divisible by data: the pool stays single-shard
        (replicated leaves, the pre-partitioning layout) and serving still
        produces identical tokens."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, (8, 13))
        kw = dict(
            n_slots=2, cache_len=64, chunk_budget=16, page_size=8, n_pages=15,
        )
        base, _ = _run(cfg, params, prompts, **kw)
        shd, s1 = _run(cfg, params, prompts, mesh_shape=(2, 1), **kw)
        assert base == shd
        assert s1.mem.data_shards == 1
        assert s1.pool is not None  # single-pool view still available

    @needs_2dev
    @pytest.mark.parametrize("policy", ["swap", "recompute"])
    def test_data_axis_preemption_is_shard_local(self, policy):
        """Preemption under a partitioned pool picks victims within the
        requester's shard; round-trips stay token-identical and every page
        returns to its shard's sub-pool on drain."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, (16, 18, 17, 20, 15, 19))
        kw = dict(
            max_new=10, n_slots=4, cache_len=64, chunk_budget=16,
            page_size=4, n_pages=20, preemption=policy,
        )
        base, _ = _run(cfg, params, prompts, **kw)
        shd, sched = _run(cfg, params, prompts, mesh_shape=(2, 1), **kw)
        assert base == shd
        assert sched.preemptions_total > 0, "pool never ran dry; tighten it"
        assert sched.mem.in_use == 0
        assert sched.mem.available_total() == sched.pages.n_pages

    @needs_2dev
    def test_data_axis_prefix_sharing_is_shard_local(self):
        """Prefix adoption under a partitioned pool: the index lives per
        sub-pool, so sharing works within a shard and never aliases pages
        across shards; greedy identity holds throughout."""
        cfg, params = _params_for("llama3.2-3b")
        rng = np.random.default_rng(7)
        shared = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
        prompts = [
            np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)]
            )
            for t in (5, 9, 6, 11)
        ]
        kw = dict(
            n_slots=4, cache_len=64, chunk_budget=16, page_size=8,
            prefix_sharing=True,
        )
        base, _ = _run(cfg, params, prompts, **kw)
        shd, s1 = _run(cfg, params, prompts, mesh_shape=(2, 1), **kw)
        assert base == shd
        assert s1.mem.in_use == 0


# ==========================================================================
# Mesh plumbing and failure modes (run everywhere)
# ==========================================================================
class TestMeshPlumbing:
    def test_make_test_mesh_fails_loudly_naming_the_flag(self):
        n = len(jax.devices()) + 1
        with pytest.raises(RuntimeError) as e:
            mesh_mod.make_test_mesh(data=1, model=n)
        msg = str(e.value)
        assert "--xla_force_host_platform_device_count" in msg
        assert "devices_required" in msg

    def test_devices_required(self):
        assert mesh_mod.devices_required(1)
        assert not mesh_mod.devices_required(len(jax.devices()) + 1)

    def test_scheduler_mesh_shape_1x1_is_noop(self):
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, mesh_shape=(1, 1)),
        )
        assert sched.sctx.mesh is None
        assert sched._layer_shardings is None
        assert sched.stats()["mesh"] is None
        assert sched.stats()["mesh_devices"] == 1

    def test_serve_sweep_mesh_shape_knob_normalizes(self):
        from repro.experiments.serve import _mesh_shape_opt

        assert _mesh_shape_opt(None) is None
        assert _mesh_shape_opt("1x2") == (1, 2)
        assert _mesh_shape_opt("2X4") == (2, 4)
        assert _mesh_shape_opt((1, 2)) == (1, 2)
        assert _mesh_shape_opt([2, 2]) == (2, 2)


# ==========================================================================
# Analytic decode roofline predictor
# ==========================================================================
class TestDecodeRoofline:
    def test_predictor_terms(self):
        from repro.launch.roofline import HBM_BW, ICI_BW, predict_decode_step

        cfg = get_config("llama3.2-3b").reduced()
        n = count_params(lm.model_schema(cfg))
        one = predict_decode_step(cfg, n, batch=4, mesh_shape=(1, 1))
        tp = predict_decode_step(cfg, n, batch=4, mesh_shape=(1, 2))
        # Single device: no collective term, memory = full weights.
        assert one.t_collective == 0.0
        assert one.hlo_bytes_per_device == n * 2
        assert one.step_time_lower_bound > 0
        # TP=2 halves per-device weight traffic and adds an all-reduce term.
        assert tp.hlo_bytes_per_device == n  # n * 2 bytes / 2 devices
        assert tp.t_collective > 0
        exp_coll = 2 * cfg.n_layers * (2 * 4 * cfg.d_model * 2 * 0.5)
        assert tp.collective_bytes_per_device == pytest.approx(exp_coll)
        assert tp.t_memory == pytest.approx((n / HBM_BW))
        assert tp.t_collective == pytest.approx(exp_coll / ICI_BW)
        assert tp.chips == 2

    def test_serve_sweep_emits_prediction(self, tmp_path):
        import repro.core as memento
        from repro.experiments import serve_matrix, serve_sweep

        matrix = serve_matrix(
            ["llama3.2-3b"], backends=["xla"], scheduler={"n_slots": [2]},
            cache_len=64, n_requests=2, prompt_lens=(4, 6),
            max_new_tokens=3, warmup=False,
        )
        eng = memento.Memento(
            serve_sweep, memento.RecordingProvider(), workdir=tmp_path,
            namespace="sharded-pred",
            runner_config=memento.RunnerConfig(
                max_workers=1, retries=0, enable_speculation=False
            ),
        )
        (r,) = eng.run(matrix)
        assert r.status == "ok"
        v = r.value
        assert v["predicted_step_ms"] > 0
        assert v["mesh"] == "1x1"
        assert v["mesh_devices"] == 1
        assert v["predicted_bottleneck"] in ("compute", "memory", "collective")

    def test_roofline_ratio_metric(self):
        from repro.analysis.metrics import MetricSpec

        from repro.experiments.serve import SERVE_METRIC_SPECS

        spec = {s.name: s for s in SERVE_METRIC_SPECS}["roofline_ratio"]
        assert spec.from_row(
            {"itl_p50_s": 0.002, "predicted_step_ms": 1.0}
        ) == pytest.approx(2.0)
        assert spec.from_row({"itl_p50_s": 0.002, "predicted_step_ms": 0}) is None
        assert spec.from_row({"predicted_step_ms": 1.0}) is None


# ==========================================================================
# Policy-file regression gate
# ==========================================================================
class TestPolicyFile:
    def test_load_policies_roundtrip(self, tmp_path):
        from repro.analysis.trajectory import RegressionPolicy, load_policies

        p = tmp_path / "policy.json"
        p.write_text(json.dumps({
            "policies": [
                {"metric": "tok_s", "max_drop": 0.25, "label": "tok/s"},
                {"metric": "itl_p50_ms", "max_drop": 0.5,
                 "higher_is_better": False},
            ]
        }))
        pols = load_policies(p)
        assert pols == (
            RegressionPolicy(metric="tok_s", max_drop=0.25, label="tok/s"),
            RegressionPolicy(
                metric="itl_p50_ms", max_drop=0.5, higher_is_better=False
            ),
        )

    def test_load_policies_missing_file_falls_back(self, tmp_path):
        from repro.analysis.trajectory import DEFAULT_POLICIES, load_policies

        assert load_policies(tmp_path / "nope.json") == DEFAULT_POLICIES

    def test_load_policies_malformed_raises(self, tmp_path):
        from repro.analysis.trajectory import load_policies

        p = tmp_path / "policy.json"
        p.write_text(json.dumps({"policies": [{"metrik": "tok_s"}]}))
        with pytest.raises(ValueError, match="unknown policy fields"):
            load_policies(p)

    def test_checked_in_policy_file_loads(self):
        import os

        from repro.analysis.trajectory import load_policies

        path = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "benchmarks", "policy.json",
        )
        pols = load_policies(path)
        assert any(p.metric == "tok_s" for p in pols)
