"""Distributed sweep runtime: file-queue race regressions + multi-process
contention/crash-recovery suite.

The four deterministic regression tests interleave the historical races by
monkeypatching one host's ``_read_claim`` to let a rival act between the
read and the mutation — each fails on the pre-tombstone protocol and passes
on the rename-based one. The multi-process tests drain one queue directory
with real worker processes (including an induced mid-task crash) and assert
exactly-once-observable completion plus key-for-key equality with a
single-host ``run()``.

Kept free of jax imports: worker processes are spawned and re-import this
module; they only need ``repro.core``.
"""
import json
import multiprocessing
import os
import threading
import time
import types
import uuid
from collections import Counter
from pathlib import Path

import pytest

from repro.core import (
    ConfigMatrix,
    FileQueue,
    Memento,
    ProgressNotificationProvider,
    RecordingProvider,
    RunnerConfig,
    drain,
)

_MP = multiprocessing.get_context("spawn")


def _matrix(n=6):
    return ConfigMatrix.from_dict({"parameters": {"i": list(range(n))}})


def _claim_owner(tmp_path, key):
    path = Path(tmp_path) / "claims" / f"{key}.claim"
    return json.loads(path.read_text())["owner"] if path.exists() else None


class TestClaimRaces:
    """Deterministic interleavings of the lease-break and release races."""

    def test_try_claim_lease_break_race(self, tmp_path):
        """Two hosts observe the same expired lease; the slower one must NOT
        destroy the winner's fresh claim (the old unlink-based break did)."""
        qa = FileQueue(tmp_path, lease_s=60, owner="host-a")
        qb = FileQueue(tmp_path, lease_s=60, owner="host-b")
        qdead = FileQueue(tmp_path, lease_s=0.05, owner="dead-host")
        specs = _matrix(1).task_list()
        qa.publish(specs)
        key = specs[0].key
        assert qdead.try_claim(key)
        time.sleep(0.1)  # dead-host's lease expires

        real_read = FileQueue._read_claim
        fired = []

        def interleaved(self, k):
            claim = real_read(self, k)
            if not fired and claim is not None and claim.get("owner") == "dead-host":
                fired.append(1)
                # B races in *after* A observed the expired lease but before
                # A breaks it: B breaks the dead lease and claims.
                assert qb.try_claim(k)
            return claim  # A still holds the stale "expired" observation

        qa._read_claim = types.MethodType(interleaved, qa)
        got = qa.try_claim(key)
        assert fired, "interleave point never hit"
        assert not got, "slower host won a claim it should have lost"
        assert _claim_owner(tmp_path, key) == "host-b"

    def test_release_does_not_destroy_reclaimed_lease(self, tmp_path):
        """release() after our lease expired and was legitimately broken +
        re-claimed by a peer must leave the peer's live claim intact (the
        old read-then-unlink deleted it)."""
        qa = FileQueue(tmp_path, lease_s=0.05, owner="host-a")
        qb = FileQueue(tmp_path, lease_s=60, owner="host-b")
        specs = _matrix(1).task_list()
        qa.publish(specs)
        key = specs[0].key
        assert qa.try_claim(key)
        time.sleep(0.1)  # A's lease expires while its task is still running

        real_read = FileQueue._read_claim
        fired = []

        def interleaved(self, k):
            claim = real_read(self, k)
            if not fired and claim is not None and claim.get("owner") == "host-a":
                fired.append(1)
                # B breaks A's expired lease and re-claims between A's
                # ownership check and A's removal of the claim file.
                assert qb.try_claim(k)
            return claim

        qa._read_claim = types.MethodType(interleaved, qa)
        qa.release(key)
        assert fired, "interleave point never hit"
        assert _claim_owner(tmp_path, key) == "host-b"
        qb.renew(key)  # B's lease is alive and renewable

    def test_renew_does_not_clobber_reclaimed_lease(self, tmp_path):
        """renew() after our lease expired and was broken + re-claimed by a
        peer must raise and leave the peer's claim intact — a blind replace
        would overwrite it and resurrect the double-ownership state."""
        from repro.core import QueueError

        qa = FileQueue(tmp_path, lease_s=0.05, owner="host-a")
        qb = FileQueue(tmp_path, lease_s=60, owner="host-b")
        specs = _matrix(1).task_list()
        qa.publish(specs)
        key = specs[0].key
        assert qa.try_claim(key)
        time.sleep(0.1)  # A's lease expires (stalled renewer)

        real_read = FileQueue._read_claim
        fired = []

        def interleaved(self, k):
            claim = real_read(self, k)
            if not fired and claim is not None and claim.get("owner") == "host-a":
                fired.append(1)
                assert qb.try_claim(k)  # peer breaks + re-claims first
            return claim

        qa._read_claim = types.MethodType(interleaved, qa)
        with pytest.raises(QueueError):
            qa.renew(key)
        assert fired, "interleave point never hit"
        assert _claim_owner(tmp_path, key) == "host-b"
        qb.renew(key)  # B's claim is alive and renewable

    def test_release_of_own_live_claim(self, tmp_path):
        q = FileQueue(tmp_path, lease_s=60, owner="h")
        specs = _matrix(1).task_list()
        q.publish(specs)
        key = specs[0].key
        assert q.try_claim(key)
        q.release(key)
        assert _claim_owner(tmp_path, key) is None
        assert q.try_claim(key)  # claimable again

    def test_no_stray_tombstones(self, tmp_path):
        q1 = FileQueue(tmp_path, lease_s=0.05, owner="h1")
        q2 = FileQueue(tmp_path, lease_s=60, owner="h2")
        specs = _matrix(1).task_list()
        q1.publish(specs)
        key = specs[0].key
        assert q1.try_claim(key)
        time.sleep(0.1)
        assert q2.try_claim(key)  # breaks via tombstone
        q2.release(key)
        left = [p.name for p in (Path(tmp_path) / "claims").iterdir()]
        assert left == [], f"leftover claim-dir entries: {left}"


def _backdate(path, age_s):
    t = time.time() - age_s
    os.utime(path, (t, t))


class TestGC:
    """``FileQueue.gc``: stale attempt records and orphaned lease debris."""

    def test_fail_records_purged_for_done_and_aged_tasks(self, tmp_path):
        q = FileQueue(tmp_path, lease_s=60, owner="h")
        specs = _matrix(3).task_list()
        q.publish(specs)
        done_k, aged_k, live_k = (s.key for s in specs)
        q.record_failure(done_k, "boom")
        q.record_failure(done_k, "boom again")
        q.mark_done(done_k, "failed")
        q.record_failure(aged_k, "old boom")
        for p in (tmp_path / "fails").glob(f"{aged_k}.*.json"):
            _backdate(p, 10 * 86400)
        q.record_failure(live_k, "fresh boom")

        out = q.gc(max_age_s=7 * 86400)
        assert out["fails_purged"] == 3
        # the done task's budget can never be consulted again; the aged
        # record crossed max_age_s; the fresh one still counts
        assert q.failure_records(done_k) == []
        assert q.failure_records(aged_k) == []
        assert len(q.failure_records(live_k)) == 1

    def test_orphan_tombstones_audited(self, tmp_path):
        q = FileQueue(tmp_path, lease_s=60, owner="h")
        specs = _matrix(2).task_list()
        q.publish(specs)
        k_dead, k_live = specs[0].key, specs[1].key
        claims = tmp_path / "claims"

        # Expired-claim tombstone from a host that died mid-break: retired.
        dead = claims / f".{k_dead}.deadbeef.tomb"
        dead.write_text(json.dumps({"owner": "x", "expires_unix": time.time() - 5}))
        _backdate(dead, 300)
        # Live-claim tombstone whose restore never ran (host died between
        # rename and link) and whose claim file is gone: restored, not lost.
        live = claims / f".{k_live}.cafef00d.tomb"
        live.write_text(
            json.dumps({"owner": "h2", "expires_unix": time.time() + 3600})
        )
        _backdate(live, 300)
        # A young tombstone is someone's in-flight steal: untouchable.
        young = claims / f".{k_dead}.0badcafe.tomb"
        young.write_text(json.dumps({"owner": "y", "expires_unix": 0}))

        out = q.gc()
        assert out["tombs_retired"] == 1
        assert out["tombs_restored"] == 1
        assert not dead.exists()
        assert not live.exists()
        assert young.exists()
        assert _claim_owner(tmp_path, k_live) == "h2"
        # restored claim is live again: not claimable until it expires
        assert not FileQueue(tmp_path, owner="h3").try_claim(k_live)

    def test_scratch_purged_and_dry_run(self, tmp_path):
        q = FileQueue(tmp_path, lease_s=60, owner="h")
        q.publish(_matrix(1).task_list())
        old_tmp = tmp_path / "tasks" / ".x.h.tmp"
        old_tmp.write_text("{}")
        _backdate(old_tmp, 300)
        old_renew = tmp_path / "claims" / "k.renew"
        old_renew.write_text("{}")
        _backdate(old_renew, 300)
        fresh_tmp = tmp_path / "done" / ".y.h.tmp"
        fresh_tmp.write_text("{}")

        dry = q.gc(dry_run=True)
        assert dry["scratch_purged"] == 2
        assert old_tmp.exists() and old_renew.exists()
        out = q.gc()
        assert out["scratch_purged"] == 2
        assert not old_tmp.exists() and not old_renew.exists()
        assert fresh_tmp.exists()
        # task/claim/done records themselves were never candidates
        assert q.pending_keys()

    def test_cli_entrypoint(self, tmp_path):
        import subprocess
        import sys

        q = FileQueue(tmp_path, lease_s=60, owner="h")
        specs = _matrix(2).task_list()
        q.publish(specs)
        q.record_failure(specs[0].key, "boom")
        q.mark_done(specs[0].key, "ok")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [str(Path(__file__).resolve().parent.parent / "src"),
             env.get("PYTHONPATH", "")]
        )
        r = subprocess.run(
            [sys.executable, "-m", "repro.core.filequeue", "gc", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=60,
        )
        assert r.returncode == 0, r.stderr
        assert "fails_purged=1" in r.stdout
        assert q.failure_records(specs[0].key) == []
        r = subprocess.run(
            [sys.executable, "-m", "repro.core.filequeue", "stats", str(tmp_path)],
            capture_output=True, text=True, env=env, timeout=60,
        )
        assert r.returncode == 0, r.stderr
        assert "total=2" in r.stdout and "done=1" in r.stdout
        r = subprocess.run(
            [sys.executable, "-m", "repro.core.filequeue", "gc",
             str(tmp_path / "nonexistent")],
            capture_output=True, text=True, env=env, timeout=60,
        )
        assert r.returncode != 0


class TestDrain:
    def test_drain_ignores_foreign_matrix_keys(self, tmp_path):
        """Keys published by a matrix version this worker doesn't know must
        not count toward termination — the old code livelocked forever."""
        specs = _matrix(3).task_list()
        foreign = ConfigMatrix.from_dict({"parameters": {"j": [10, 11]}}).task_list()
        pub = FileQueue(tmp_path, owner="pub")
        pub.publish(specs)
        pub.publish(foreign)
        by_key = {s.key: s for s in specs}
        out = {}

        def worker():
            q = FileQueue(tmp_path, lease_s=60, owner="w")
            out.update(
                drain(q, by_key, lambda s, beat: s.params["i"],
                      idle_rounds=2, idle_sleep_s=0.02)
            )

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        t.join(timeout=15)
        assert not t.is_alive(), "drain() livelocked on foreign-published keys"
        assert set(out) == set(by_key)
        # the foreign keys are untouched, not claimed/failed
        assert all(not pub.is_done(s.key) for s in foreign)

    def test_drain_failure_records_and_cross_host_budget(self, tmp_path):
        specs = _matrix(1).task_list()
        key = specs[0].key
        q1 = FileQueue(tmp_path, lease_s=60, owner="h1")
        q2 = FileQueue(tmp_path, lease_s=60, owner="h2")
        q1.publish(specs)
        by_key = {s.key: s for s in specs}

        # Host 1 fails the task once mid-drain: attempt recorded, claim
        # released, nothing terminal yet (this is exactly what drain() does
        # on a non-terminal failure).
        assert q1.try_claim(key)
        assert q1.record_failure(key, "ValueError: original kaboom",
                                 "Traceback ... ValueError: original kaboom") == 1
        q1.release(key)
        assert not q1.is_done(key)

        def boom(spec, beat):
            raise RuntimeError("later failure on h2")

        # Host 2 exhausts the cross-host budget: terminal, with the
        # *original* error + traceback + attempt count in the done record.
        res2 = drain(q2, by_key, boom, idle_rounds=1, idle_sleep_s=0.01,
                     max_attempts=2)
        assert res2 == {key: "failed"}
        rec = q2.read_done(key)
        assert rec["status"] == "failed"
        assert rec["error"] == "ValueError: original kaboom"
        assert "ValueError" in rec["traceback"]
        assert rec["attempts"] == 2
        assert rec["last_error"] == "RuntimeError: later failure on h2"
        assert rec["owner"] == "h2"

    def test_stats_key_scoping(self, tmp_path):
        specs = _matrix(2).task_list()
        foreign = ConfigMatrix.from_dict({"parameters": {"j": [1]}}).task_list()
        q = FileQueue(tmp_path, owner="h")
        q.publish(specs)
        q.publish(foreign)
        known = {s.key for s in specs}
        assert q.stats().total == 3
        assert q.stats(keys=known).total == 2
        assert q.try_claim(foreign[0].key)
        assert q.stats().claimed == 1
        assert q.stats(keys=known).claimed == 0


def exec_and_value(ctx):
    """Experiment function for the multi-process suite: records every
    execution as a unique file (exactly-once observability), then returns a
    pure function of the params."""
    d = Path(ctx.settings["execdir"])
    (d / f"{ctx.key}.{uuid.uuid4().hex}").touch()
    marker = ctx.settings.get("crash_marker")
    if marker and ctx["i"] == ctx.settings["crash_i"] and not Path(marker).exists():
        Path(marker).touch()
        os._exit(23)  # simulated host death: leases left behind must expire
    time.sleep(ctx.settings.get("delay", 0.01))
    return ctx["i"] * 7


def _worker_main(root, matrix, owner, lease_s):
    eng = Memento(
        exec_and_value,
        workdir=os.path.join(root, "w"),
        runner_config=RunnerConfig(max_workers=2, enable_speculation=False, retries=0),
    )
    eng.run_distributed(
        matrix, queue_dir=os.path.join(root, "q"), lease_s=lease_s, owner=owner
    )


def _mk_matrix(root, n, crash=False):
    settings = {"execdir": os.path.join(root, "exec"), "delay": 0.01}
    if crash:
        settings.update(crash_marker=os.path.join(root, "crashed"), crash_i=2)
    return {"parameters": {"i": list(range(n))}, "settings": settings}


def _exec_counts(root):
    return Counter(p.name.split(".")[0] for p in (Path(root) / "exec").iterdir())


class TestMultiProcess:
    def _run_workers(self, root, matrix, n_procs, lease_s, timeout=120):
        procs = [
            _MP.Process(target=_worker_main, args=(root, matrix, f"w{i}", lease_s))
            for i in range(n_procs)
        ]
        for p in procs:
            p.start()
        for p in procs:
            p.join(timeout=timeout)
        codes = [p.exitcode for p in procs]
        assert all(c is not None for c in codes), f"worker hung: {codes}"
        return codes

    def test_contention_exactly_once_and_matches_single_host(self, tmp_path):
        n = 12
        root = str(tmp_path)
        (tmp_path / "exec").mkdir()
        matrix = _mk_matrix(root, n)
        codes = self._run_workers(root, matrix, n_procs=3, lease_s=60)
        assert codes == [0, 0, 0]
        # Exactly-once observable: each of the n tasks executed exactly once
        # across all three processes (claims were exclusive, no lease broke).
        counts = _exec_counts(root)
        assert len(counts) == n
        assert set(counts.values()) == {1}, f"double-executed: {counts}"
        # Any host (here: the parent, which ran nothing) assembles the full
        # ResultSet from the shared cache/queue...
        eng = Memento(exec_and_value, workdir=tmp_path / "w")
        assembled = eng.run_distributed(
            matrix, queue_dir=tmp_path / "q", publish=False
        )
        assert sorted(r.value for r in assembled) == [i * 7 for i in range(n)]
        assert all(r.ok for r in assembled)
        # ...and it equals a single-host run() key-for-key.
        single = Memento(
            exec_and_value,
            workdir=tmp_path / "w-single",
            runner_config=RunnerConfig(max_workers=4, enable_speculation=False),
        ).run(matrix)
        assert {r.spec.key: r.value for r in single} == {
            r.spec.key: r.value for r in assembled
        }

    def test_killed_worker_recovered_via_lease_break(self, tmp_path):
        n = 8
        root = str(tmp_path)
        (tmp_path / "exec").mkdir()
        matrix = _mk_matrix(root, n, crash=True)
        codes = self._run_workers(root, matrix, n_procs=3, lease_s=1.0)
        # exactly one worker died mid-task; the others (or a lease break by
        # whoever was still draining) completed the whole matrix anyway
        assert sorted(codes) == [0, 0, 23], codes
        eng = Memento(exec_and_value, workdir=tmp_path / "w")
        assembled = eng.run_distributed(
            matrix, queue_dir=tmp_path / "q", publish=False, lease_s=1.0
        )
        assert sorted(r.value for r in assembled) == [i * 7 for i in range(n)]
        counts = _exec_counts(root)
        assert len(counts) == n
        # the crashed task (and any task the dead worker had in flight) was
        # re-executed after its lease expired; nothing ran more than twice
        assert all(1 <= c <= 2 for c in counts.values()), counts


class TestDistributedRuntime:
    """Single-process (thread-level) behaviours of the Runner-backed drain."""

    def test_peer_failure_surfaces_real_error(self, tmp_path):
        """A task that failed on a peer host must surface that host's real
        error + traceback, never a generic 'failed on a peer host'."""

        def boom(ctx):
            if ctx["i"] == 1:
                raise ValueError("actual root cause 42")
            return ctx["i"]

        matrix = {"parameters": {"i": [0, 1]}}
        eng_a = Memento(
            boom, workdir=tmp_path / "w",
            runner_config=RunnerConfig(max_workers=2, enable_speculation=False,
                                       retries=0),
        )
        res_a = eng_a.run_distributed(
            matrix, queue_dir=tmp_path / "q", max_attempts=1, owner="host-a"
        )
        # ...as seen by the executing host itself,
        failed_a = [r for r in res_a if not r.ok]
        assert len(failed_a) == 1
        assert "actual root cause 42" in failed_a[0].error
        assert "peer host" not in failed_a[0].error
        # ...and by a peer that only observes the done record.
        eng_b = Memento(boom, workdir=tmp_path / "w")
        res_b = eng_b.run_distributed(
            matrix, queue_dir=tmp_path / "q", max_attempts=1, owner="host-b"
        )
        failed_b = [r for r in res_b if not r.ok]
        assert len(failed_b) == 1
        assert "actual root cause 42" in failed_b[0].error
        assert "peer host" not in failed_b[0].error
        assert failed_b[0].host == "host-a"
        assert "ValueError" in failed_b[0].traceback_str
        assert [r.value for r in res_b if r.ok] == [0]

    def test_cross_host_retry_until_budget_then_success(self, tmp_path):
        execs = tmp_path / "execs"
        execs.mkdir()

        def flaky(ctx):
            n_before = len(list(execs.iterdir()))
            (execs / f"e{n_before}").touch()
            if n_before < 2:
                raise RuntimeError(f"transient {n_before}")
            return "recovered"

        eng = Memento(
            flaky, workdir=tmp_path / "w",
            runner_config=RunnerConfig(max_workers=1, enable_speculation=False,
                                       retries=0),
        )
        res = eng.run_distributed(
            {"parameters": {"i": [0]}}, queue_dir=tmp_path / "q", max_attempts=3
        )
        assert res[0].ok and res[0].value == "recovered"
        assert len(list(execs.iterdir())) == 3  # two queue retries, then ok
        q = FileQueue(tmp_path / "q")
        assert len(q.failure_records(res[0].spec.key)) == 2

    def test_lease_renewal_thread_covers_heartbeat_free_tasks(self, tmp_path):
        """A long task that never calls ctx.heartbeat() must keep its lease:
        a rival host polling the queue the whole time never steals the task,
        so it executes exactly once."""
        execs = tmp_path / "execs"
        execs.mkdir()

        def slow(ctx):
            (execs / uuid.uuid4().hex).touch()
            time.sleep(1.0)  # >> lease_s, no heartbeat calls
            return "done"

        matrix = {"parameters": {"i": [0]}}
        results = {}

        def host(name):
            eng = Memento(
                slow, workdir=tmp_path / "w",
                runner_config=RunnerConfig(max_workers=1,
                                           enable_speculation=False, retries=0),
            )
            results[name] = eng.run_distributed(
                matrix, queue_dir=tmp_path / "q", lease_s=0.3, owner=name
            )

        t1 = threading.Thread(target=host, args=("h1",), daemon=True)
        t2 = threading.Thread(target=host, args=("h2",), daemon=True)
        t1.start(); t2.start()
        t1.join(timeout=30); t2.join(timeout=30)
        assert not t1.is_alive() and not t2.is_alive()
        assert len(list(execs.iterdir())) == 1, "lease expired mid-task"
        for name in ("h1", "h2"):
            assert [r.ok for r in results[name]] == [True]

    def test_stream_yields_cache_hits_first_then_live(self, tmp_path):
        def f(ctx):
            return ctx["i"] * 2

        eng = Memento(f, workdir=tmp_path / "w",
                      runner_config=RunnerConfig(max_workers=2,
                                                 enable_speculation=False))
        eng.run({"parameters": {"i": [0]}})  # warm one cell of the cache
        seen = [
            r.status
            for r in eng.stream_distributed(
                {"parameters": {"i": [0, 1, 2]}}, queue_dir=tmp_path / "q"
            )
        ]
        assert seen[0] == "cached"
        assert sorted(seen[1:]) == ["ok", "ok"]

    def test_queue_progress_events_and_provider_rendering(self, tmp_path):
        import io

        rec = RecordingProvider()

        def f(ctx):
            return ctx["i"]

        eng = Memento(
            f, rec, workdir=tmp_path / "w",
            runner_config=RunnerConfig(max_workers=2, enable_speculation=False),
        )
        from repro.core import DistributedConfig

        res = eng.run_distributed(
            {"parameters": {"i": [0, 1, 2]}}, queue_dir=tmp_path / "q",
            owner="me", distributed_config=DistributedConfig(progress_every_s=0.0),
        )
        assert all(r.ok for r in res)
        prog = [e for e in rec.events if e.kind == "queue_progress"]
        assert prog
        assert prog[-1].payload["total"] == 3
        assert "claimed_by" in prog[-1].payload and "done_by" in prog[-1].payload
        # structured-event schema: every queue_progress snapshot carries the
        # fleet fields the dashboard consumes
        for e in prog:
            p = e.payload
            assert set(p) >= {"total", "done", "failed", "claimed_by",
                              "done_by", "owner", "elapsed_s", "eta_s"}
            assert p["owner"] == "me"
            assert p["elapsed_s"] >= 0.0
            assert p["eta_s"] is None or p["eta_s"] >= 0.0
        # run_started announces the matrix size for ETA math downstream
        started = [e for e in rec.events if e.kind == "run_started"]
        assert started and started[0].payload["total"] == 3
        assert started[0].payload["workers"] == 2
        # task_finished events carry host/wall_s/params/metrics
        fin = [e for e in rec.events if e.kind == "task_finished"]
        assert fin
        for e in fin:
            p = e.payload
            assert set(p) >= {"key", "status", "params", "host", "wall_s",
                              "attempts", "cached", "metrics"}
            assert p["host"]
            assert "i" in p["params"]
        rec_dict = fin[0].to_record()
        assert rec_dict["kind"] == "task_finished" and rec_dict["key"]
        # ProgressNotificationProvider renders the per-host queue line
        buf = io.StringIO()
        prov = ProgressNotificationProvider(total=3, stream=buf)
        prov.notify(prog[-1])
        line = buf.getvalue()
        assert "queue" in line and "/3 done" in line
        assert prov.queue_state["total"] == 3

    def test_queue_state_converges_for_warm_caches(self, tmp_path):
        def f(ctx):
            return ctx["i"]

        eng = Memento(f, workdir=tmp_path / "w")
        eng.run({"parameters": {"i": [0, 1]}})
        eng.run_distributed({"parameters": {"i": [0, 1]}}, queue_dir=tmp_path / "q")
        q = FileQueue(tmp_path / "q")
        # cache-hit tasks were marked done so the queue itself drains
        assert q.stats().done == 2
        assert q.pending_keys() == []
