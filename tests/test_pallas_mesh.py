"""Paged Pallas kernels under a multi-device mesh (shard_map).

GSPMD cannot partition a pallas_call, so the serving step wraps the paged
decode/chunk kernels in shard_map with per-shard page-id localization
(see kernels/ops.py + models/attention.py ``_paged_kernel_specs``). These
tests pin:

  * op-level identity: the shard_map'd kernel reproduces the
    single-device kernel bit-for-bit under model- and data-sharded
    meshes, including global->local page-id translation against a truly
    partitioned pool;
  * end-to-end identity: serving with ``attn_backend="pallas"`` under a
    mesh emits exactly the tokens of the XLA gather path, and the
    sharded kernel wrapper is actually on the traced path (not silently
    falling back);
  * clean fallbacks: layouts that can't partition (indivisible heads,
    single-slot chunks under a data axis) return None from the spec
    resolver and take the XLA path.

Multi-device cases skip on 1-device CI; the sharded-smoke lane forces 8
host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels import ops as kops
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.models.attention import _paged_kernel_specs
from repro.models.schema import init_params
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx, get_profile

needs_2dev = pytest.mark.skipif(
    not mesh_mod.devices_required(2),
    reason="needs >=2 XLA devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

from jax.sharding import PartitionSpec as P  # noqa: E402


# ==========================================================================
# Op level: shard_map'd kernel vs the single-device kernel
# ==========================================================================
def _pool_problem(rng, *, n_slots, per_shard, shards, page, KV, D, H):
    """A paged-decode problem over a pool laid out in per-shard blocks
    (each block's last row is its trash page), page tables shard-local."""
    stride = per_shard + 1
    total = shards * stride
    k_pool = jnp.asarray(rng.normal(size=(total, page, KV, D)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(total, page, KV, D)), jnp.float32)
    max_pages = per_shard // (n_slots // shards)
    pt = np.zeros((n_slots, max_pages), np.int32)
    pos = np.zeros((n_slots,), np.int32)
    slots_per_shard = n_slots // shards
    for s in range(n_slots):
        sh = s * shards // n_slots
        base = sh * stride + (s % slots_per_shard) * max_pages
        held = 1 + (s % max_pages)
        row = [base + j for j in range(held)]
        row += [sh * stride + per_shard] * (max_pages - held)  # trash fill
        pt[s] = row
        pos[s] = held * page - 1 - (s % page)
    q = jnp.asarray(rng.normal(size=(n_slots, 1, H, D)), jnp.float32)
    return q, k_pool, v_pool, jnp.asarray(pt), jnp.asarray(pos), max_pages


class TestOpIdentity:
    @needs_2dev
    def test_model_sharded_kernel_matches_single_device(self):
        """(1, 2) mesh: heads split over model, pool replicated — the
        shard_map'd kernel equals the direct call."""
        rng = np.random.default_rng(0)
        q, k, v, pt, pos, n_lp = _pool_problem(
            rng, n_slots=4, per_shard=8, shards=1, page=4, KV=2, D=8, H=4
        )
        ref = kops.paged_decode_attention_op(q, k, v, pt, pos, n_lp=n_lp)
        mesh = mesh_mod.make_test_mesh(data=1, model=2)
        out = kops.paged_decode_attention_sharded(
            q, k, v, pt, pos, n_lp=n_lp, mesh=mesh,
            q_spec=P(None, None, "model", None),
            pool_spec=P(None, None, "model", None),
            table_spec=P(None, None), vec_spec=P(None),
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)

    @needs_2dev
    def test_data_sharded_kernel_localizes_page_ids(self):
        """(2, 1) mesh over a truly partitioned pool: each shard sees its
        sub-pool with local ids; output equals the global single-device
        kernel fed the global table."""
        rng = np.random.default_rng(1)
        q, k, v, pt, pos, n_lp = _pool_problem(
            rng, n_slots=4, per_shard=8, shards=2, page=4, KV=2, D=8, H=4
        )
        ref = kops.paged_decode_attention_op(q, k, v, pt, pos, n_lp=n_lp)
        mesh = mesh_mod.make_test_mesh(data=2, model=1)
        out = kops.paged_decode_attention_sharded(
            q, k, v, pt, pos, n_lp=n_lp, mesh=mesh,
            q_spec=P("data", None, None, None),
            pool_spec=P("data", None, None, None),
            table_spec=P("data", None), vec_spec=P("data"),
            localize_pages=True,
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)

    @needs_2dev
    def test_chunk_kernel_model_sharded(self):
        """Chunked-prefill kernel under (1, 2): head-split shard_map equals
        the direct call (single-slot chunk, pool replicated)."""
        rng = np.random.default_rng(2)
        page, KV, D, H, C = 4, 2, 8, 4, 8
        total, n_lp = 7, 4
        k = jnp.asarray(rng.normal(size=(total, page, KV, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(total, page, KV, D)), jnp.float32)
        pt = jnp.asarray([[0, 1, 2, 6]], jnp.int32)  # 6 == trash
        start = jnp.asarray([5], jnp.int32)
        q = jnp.asarray(rng.normal(size=(1, C, H, D)), jnp.float32)
        ref = kops.paged_chunk_attention_op(q, k, v, pt, start, n_lp=n_lp)
        mesh = mesh_mod.make_test_mesh(data=1, model=2)
        out = kops.paged_chunk_attention_sharded(
            q, k, v, pt, start, n_lp=n_lp, mesh=mesh,
            q_spec=P(None, None, "model", None),
            pool_spec=P(None, None, "model", None),
            table_spec=P(None, None), vec_spec=P(None),
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# ==========================================================================
# Spec resolution: when shard_map applies vs XLA fallback
# ==========================================================================
class TestSpecResolution:
    def test_single_device_returns_none(self):
        assert _paged_kernel_specs(
            ShardingCtx.null(), B=4, H=4, KV=2, total_pages=10,
            batch_sharded=True,
        ) is None

    @needs_2dev
    def test_indivisible_heads_fall_back(self):
        sctx = ShardingCtx(
            mesh_mod.make_test_mesh(data=1, model=2),
            get_profile("decode_default"),
        )
        assert _paged_kernel_specs(
            sctx, B=4, H=3, KV=1, total_pages=10, batch_sharded=True
        ) is None

    @needs_2dev
    def test_chunk_under_data_axis_falls_back(self):
        sctx = ShardingCtx(
            mesh_mod.make_test_mesh(data=2, model=1),
            get_profile("decode_default"),
            pool_data_shards=2,
        )
        assert _paged_kernel_specs(
            sctx, B=1, H=4, KV=2, total_pages=10, batch_sharded=False
        ) is None

    @needs_2dev
    def test_replicated_pool_under_data_axis_does_not_localize(self):
        """data > 1 with a single-shard pool (pool_data_shards == 1): the
        batch still splits but page ids stay global."""
        sctx = ShardingCtx(
            mesh_mod.make_test_mesh(data=2, model=1),
            get_profile("decode_default"),
        )
        specs = _paged_kernel_specs(
            sctx, B=4, H=4, KV=2, total_pages=16, batch_sharded=True
        )
        assert specs is not None
        assert specs["localize_pages"] is False
        assert specs["pool_spec"] == P(None, None, None, None)

    @needs_2dev
    def test_partitioned_pool_localizes(self):
        sctx = ShardingCtx(
            mesh_mod.make_test_mesh(data=2, model=1),
            get_profile("decode_default"),
            pool_data_shards=2,
        )
        specs = _paged_kernel_specs(
            sctx, B=4, H=4, KV=2, total_pages=18, batch_sharded=True
        )
        assert specs is not None
        assert specs["localize_pages"] is True
        assert specs["pool_spec"] == P("data", None, None, None)
        assert specs["table_spec"] == P("data", None)


# ==========================================================================
# End to end: serving with the Pallas backend under a mesh
# ==========================================================================
def _serve(cfg, params, prompts, **kw):
    sched = Scheduler(cfg, params, ShardingCtx.null(), SchedulerConfig(**kw))
    for p in prompts:
        sched.submit(Request(prompt=p, max_new_tokens=6))
    return [rs.tokens for rs in sched.run()], sched


class TestEndToEndIdentity:
    @needs_2dev
    @pytest.mark.parametrize("mesh_shape", [(1, 2), (2, 1)])
    def test_pallas_under_mesh_matches_xla_gather(self, mesh_shape, monkeypatch):
        """Serving with the Pallas backend under a mesh is token-identical
        to the XLA gather path, and the shard_map'd decode kernel really
        is on the traced path."""
        base = get_config("llama3.2-3b").reduced()
        params = init_params(lm.model_schema(base), jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        prompts = [
            rng.integers(0, base.vocab_size, size=t).astype(np.int32)
            for t in (8, 21, 13, 9)
        ]
        kw = dict(n_slots=4, cache_len=64, chunk_budget=16, page_size=8)

        cfg_x = replace(base, attn_backend="xla")
        ref, _ = _serve(cfg_x, params, prompts, mesh_shape=mesh_shape, **kw)

        hits = {"decode": 0}
        orig = kops.paged_decode_attention_sharded

        def spy(*a, **k):
            hits["decode"] += 1
            return orig(*a, **k)

        monkeypatch.setattr(kops, "paged_decode_attention_sharded", spy)
        cfg_p = replace(base, attn_backend="pallas")
        out, sched = _serve(cfg_p, params, prompts, mesh_shape=mesh_shape, **kw)
        assert out == ref
        assert hits["decode"] > 0, "sharded kernel never traced; fallback?"
        if mesh_shape == (2, 1):
            assert sched.mem.data_shards == 2
            assert sched.sctx.pool_data_shards == 2

    @needs_2dev
    def test_pallas_under_mesh_matches_single_device_pallas(self):
        """Same backend, with and without the mesh: the shard_map path
        changes layout, never tokens."""
        base = get_config("llama3.2-3b").reduced()
        cfg = replace(base, attn_backend="pallas")
        params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(6)
        prompts = [
            rng.integers(0, cfg.vocab_size, size=t).astype(np.int32)
            for t in (8, 17)
        ]
        kw = dict(n_slots=2, cache_len=64, chunk_budget=16, page_size=8)
        ref, _ = _serve(cfg, params, prompts, **kw)
        out, _ = _serve(cfg, params, prompts, mesh_shape=(1, 2), **kw)
        assert out == ref
