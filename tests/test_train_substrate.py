"""Optimizer math, checkpoint store, data pipeline, training loop
(incl. kill -> resume), sharding rule resolution."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.ckpt.store import CheckpointStore
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, make_batch_fn
from repro.sharding.rules import ShardingCtx, get_profile, pspec_for
from repro.train.optimizer import (
    AdamW,
    AdamWConfig,
    Schedule,
    clip_by_global_norm,
    q8_dequantize,
    q8_quantize,
)


class TestOptimizer:
    def test_adamw_matches_closed_form_step(self):
        cfg = AdamWConfig(
            schedule=Schedule(base_lr=0.1, warmup_steps=0, kind="const"),
            b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0, clip_norm=1e9,
        )
        opt = AdamW(cfg)
        p = {"w": jnp.array([1.0, -2.0])}
        g = {"w": jnp.array([0.5, 0.5])}
        st_ = opt.init(p)
        new_p, st2, _ = opt.update(g, st_, p)
        # closed form for step 1: m_hat = g, v_hat = g^2 -> update = g/(|g|+eps)
        expect = p["w"] - 0.1 * (g["w"] / (jnp.abs(g["w"]) + 1e-8))
        np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect), rtol=1e-5)
        assert int(st2["step"]) == 1

    def test_weight_decay_direction(self):
        cfg = AdamWConfig(
            schedule=Schedule(base_lr=0.1, warmup_steps=0, kind="const"),
            weight_decay=0.5, clip_norm=1e9,
        )
        opt = AdamW(cfg)
        p = {"w": jnp.array([10.0])}
        g = {"w": jnp.array([0.0])}
        new_p, _, _ = opt.update(g, opt.init(p), p)
        assert float(new_p["w"][0]) < 10.0  # decays toward zero

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        total = math.sqrt(sum(float(jnp.sum(x * x)) for x in jax.tree.leaves(clipped)))
        assert abs(total - 1.0) < 1e-5
        assert abs(float(norm) - math.sqrt(90 + 160)) < 1e-3

    def test_schedule_warmup_and_decay(self):
        s = Schedule(base_lr=1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(s(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)

    def test_int8_state_memory_and_training(self):
        cfg = AdamWConfig(
            schedule=Schedule(base_lr=0.05, warmup_steps=0, kind="const"),
            int8_moments=True, clip_norm=1e9, weight_decay=0.0,
        )
        opt = AdamW(cfg)
        p = {"w": jnp.array(np.random.RandomState(0).randn(256).astype(np.float32))}
        state = opt.init(p)
        assert state["m"]["w"]["codes"].dtype == jnp.int8
        # a few steps on a quadratic: loss must fall
        target = jnp.zeros(256)
        for _ in range(20):
            g = {"w": 2 * (p["w"] - target)}
            p, state, _ = opt.update(g, state, p)
        assert float(jnp.mean(p["w"] ** 2)) < 0.5

    @given(st.integers(0, 1000), st.floats(0.01, 100.0))
    @settings(max_examples=20, deadline=None)
    def test_q8_roundtrip_error_bound(self, seed, scale):
        x = jnp.asarray(np.random.RandomState(seed).randn(300) * scale, jnp.float32)
        err = jnp.abs(q8_dequantize(q8_quantize(x)) - x)
        # per-block bound: absmax/127 per element
        blocks = jnp.pad(x, (0, (-x.shape[0]) % 128)).reshape(-1, 128)
        bound = jnp.repeat(jnp.max(jnp.abs(blocks), axis=1) / 127.0, 128)[: x.shape[0]]
        assert bool(jnp.all(err <= bound * 1.01 + 1e-9))


class TestShardingRules:
    def test_divisibility_fallback(self):
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(1, 1)
        prof = get_profile("dp_tp")
        # size-1 mesh axes are never emitted into specs
        spec = pspec_for((24, 128), ("heads", "head_dim"), prof, mesh)
        assert spec == jax.sharding.PartitionSpec()

    def test_no_axis_reuse_within_tensor(self):
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(1, 1)
        prof = get_profile("fsdp_tp")
        spec = pspec_for((64, 64), ("embed", "embed"), prof, mesh)
        flat = [a for e in spec for a in ((e,) if isinstance(e, str) else (e or ()))]
        assert len(flat) == len(set(flat))

    def test_profiles_exist(self):
        for name in ("dp_tp", "dp_wide", "fsdp_tp", "fsdp_wide", "decode_default", "decode_big", "decode_long"):
            assert get_profile(name).rules


class TestCheckpointStore:
    def _state(self, x=0.0):
        return {
            "params": {"w": jnp.full((4, 4), 1.0 + x), "b": jnp.zeros(3)},
            "step": jnp.asarray(int(x), jnp.int32),
        }

    def test_roundtrip(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(5, self._state(5.0))
        step, restored = store.restore(self._state())
        assert step == 5
        assert float(restored["params"]["w"][0, 0]) == 6.0

    def test_keep_last_k(self, tmp_path):
        store = CheckpointStore(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            store.save(s, self._state(float(s)))
        assert store.all_steps() == [3, 4]
        assert store.latest_step() == 4

    def test_async_write(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(1, self._state(1.0), blocking=False)
        store.wait()
        assert store.latest_step() == 1

    def test_restore_with_target_sharding(self, tmp_path):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(1, 1)
        store = CheckpointStore(tmp_path)
        store.save(1, self._state(2.0))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), self._state())
        _, restored = store.restore(self._state(), shardings=sh)
        assert restored["params"]["w"].sharding == NamedSharding(mesh, P())

    def test_tree_mismatch_rejected(self, tmp_path):
        store = CheckpointStore(tmp_path)
        store.save(1, self._state())
        from repro.core.exceptions import CheckpointError

        with pytest.raises(CheckpointError):
            store.restore({"params": {"other": jnp.zeros(3)}})


class TestDataPipeline:
    def test_determinism(self):
        src = SyntheticLM(DataConfig(seed=1, vocab_size=100))
        b1 = src.batch(3, 8, 16)
        b2 = src.batch(3, 8, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        src = SyntheticLM(DataConfig(seed=1, vocab_size=100))
        assert not np.array_equal(src.batch(0, 8, 16)["tokens"], src.batch(1, 8, 16)["tokens"])

    def test_host_shards_disjoint_and_cover(self):
        src = SyntheticLM(DataConfig(seed=1, vocab_size=100))
        full = src.batch(0, 8, 16)
        h0 = src.batch(0, 8, 16, host_index=0, host_count=2)
        h1 = src.batch(0, 8, 16, host_index=1, host_count=2)
        np.testing.assert_array_equal(np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])

    def test_labels_are_shifted_tokens(self):
        src = SyntheticLM(DataConfig(seed=0, vocab_size=50))
        b = src.batch(0, 2, 16)
        # labels[t] is the next token after tokens[t] by construction
        assert b["tokens"].shape == b["labels"].shape == (2, 16)


class TinyShape(ShapeConfig):
    pass


@pytest.fixture(scope="module")
def tiny_train():
    cfg = get_config("llama3.2-3b").reduced()
    shape = ShapeConfig("tiny", "train", seq_len=32, global_batch=4)
    return cfg, shape


class TestTrainLoop:
    def test_loss_decreases_and_resume_matches(self, tiny_train, tmp_path):
        from repro.train.loop import TrainRunConfig, train_run
        from repro.train.optimizer import AdamWConfig, Schedule

        cfg, shape = tiny_train
        sctx = ShardingCtx.null()
        opt = AdamWConfig(schedule=Schedule(base_lr=3e-3, warmup_steps=5, kind="const"))
        run = TrainRunConfig(
            steps=16, ckpt_every=8, log_every=4, opt=opt,
            ckpt_dir=str(tmp_path / "a"),
            data=DataConfig(seed=0, vocab_size=cfg.vocab_size, noise=0.02),
        )
        res = train_run(cfg, shape, sctx, run)
        assert res["loss_last"] < res["loss_first"], res

        # interrupted run: first 8 steps land a checkpoint ...
        run_b1 = TrainRunConfig(
            steps=8, ckpt_every=8, log_every=4, opt=opt, ckpt_dir=str(tmp_path / "b"),
            data=run.data,
        )
        train_run(cfg, shape, sctx, run_b1)
        # ... then a fresh loop resumes at 8 and finishes at 16
        run_b2 = TrainRunConfig(
            steps=16, ckpt_every=8, log_every=4, opt=opt, ckpt_dir=str(tmp_path / "b"),
            data=run.data,
        )
        res_b = train_run(cfg, shape, sctx, run_b2)
        # deterministic data + deterministic init => identical final loss
        assert res_b["loss_last"] == pytest.approx(res["loss_last"], rel=1e-3)
