"""Experiment API v2: streaming execution, lazy ResultSet, dry-run provider,
per-task attempt accounting."""
import time

import pytest

from repro.core import (
    ConfigMatrix,
    Context,
    FsCache,
    Memento,
    MemoryCache,
    RecordingProvider,
    ResultSet,
    Runner,
    RunnerConfig,
    TaskResult,
)


def _matrix(n=6):
    return ConfigMatrix.from_dict({"parameters": {"i": list(range(n))}})


def square(ctx: Context):
    return ctx["i"] ** 2


def one_slow(ctx: Context):
    time.sleep(1.5 if ctx["i"] == 0 else 0.01)
    return ctx["i"]


class TestStreaming:
    def test_results_arrive_before_slowest_finishes(self):
        """The defining property of stream(): fast tasks land while the
        straggler is still running."""
        eng = Memento(
            one_slow,
            runner_config=RunnerConfig(max_workers=4, enable_speculation=False),
        )
        t0 = time.time()
        arrivals = []
        for r in eng.stream(_matrix(4)):
            arrivals.append((r.spec.params["i"], time.time() - t0))
        by_i = dict(arrivals)
        assert set(by_i) == {0, 1, 2, 3}
        # The three fast tasks streamed out well before the 1.5s straggler.
        fast = [t for i, t in arrivals if i != 0]
        assert max(fast) < 1.0
        assert by_i[0] >= 1.0
        # And the slow task arrived last.
        assert arrivals[-1][0] == 0

    def test_cached_results_stream_first(self, tmp_path):
        eng = Memento(one_slow, workdir=tmp_path)
        # Prime the cache with everything but the slow task.
        eng.run(ConfigMatrix.from_dict({"parameters": {"i": [1, 2, 3]}}))
        order = [r.status for r in eng.stream(_matrix(4))]
        assert order == ["cached", "cached", "cached", "ok"]

    def test_run_is_collector_over_stream(self):
        res = Memento(square).run(_matrix(5))
        assert isinstance(res, ResultSet)
        assert res.values == [i * i for i in range(5)]

    def test_runner_stream_collapses_duplicate_keys(self):
        specs = _matrix(3).task_list()
        r = Runner(square, config=RunnerConfig(max_workers=2, enable_speculation=False))
        results = list(r.stream(specs + specs))
        assert len(results) == 3

    def test_stats_populated_after_stream(self):
        r = Runner(square, config=RunnerConfig(max_workers=2, enable_speculation=False))
        list(r.stream(_matrix(4).task_list()))
        assert r.stats["ok"] == 4 and r.stats["failed"] == 0


class TestResultSetV2:
    def _results(self):
        return Memento(square).run(_matrix(4))

    def test_ok_failed_both_spellings(self):
        def mixed(ctx):
            if ctx["i"] == 1:
                raise ValueError("boom")
            return ctx["i"]

        res = Memento(
            mixed, runner_config=RunnerConfig(max_workers=2, retries=0, enable_speculation=False)
        ).run(_matrix(3))
        assert len(res.ok) == 2 and len(res.ok()) == 2  # property and call
        assert len(res.failed) == 1 and len(res.failed()) == 1
        assert res.ok() == res.ok

    def test_lazy_assembly_from_stream(self):
        eng = Memento(square)
        consumed = []

        def tracking():
            for r in eng.stream(_matrix(3)):
                consumed.append(r)
                yield r

        rs = ResultSet(tracking())
        assert consumed == []  # nothing drained yet
        assert len(rs) == 3  # first access assembles
        assert len(consumed) == 3

    def test_pivot(self):
        def cell(ctx):
            return ctx["a"] * 10 + ctx["b"]

        res = Memento(cell).run(
            {"parameters": {"a": [1, 2], "b": [3, 4]}, "exclude": [{"a": 2, "b": 4}]}
        )
        p = res.pivot("a", "b")
        assert p.rows == [1, 2] and p.cols == [3, 4]
        assert p.cells == [[13, 14], [23, None]]
        assert "a\\b" in str(p)

    def test_pivot_value_fn(self):
        res = self._results()
        p = res.pivot("i", "i", value_fn=lambda r: r.wall_s >= 0)
        assert all(p.cells[i][i] for i in range(4))

    def _seeded(self):
        # Two tasks land in every (a, b) cell: the seed axis varies.
        def cell(ctx):
            return ctx["a"] * 10 + ctx["seed"]

        return Memento(cell).run(
            {"parameters": {"a": [1, 2], "b": [3], "seed": [0, 4]}}
        )

    def test_pivot_ambiguous_cells_raise_by_default(self):
        res = self._seeded()
        with pytest.raises(ValueError, match="ambiguous"):
            res.pivot("a", "b")

    def test_pivot_agg_resolves_duplicates(self):
        res = self._seeded()
        assert res.pivot("a", "b", agg="mean").cells == [[12.0], [22.0]]
        assert res.pivot("a", "b", agg="max").cells == [[14], [24]]
        assert res.pivot("a", "b", agg="count").cells == [[2], [2]]
        # "last" reproduces the historical last-task-index-wins behavior
        assert res.pivot("a", "b", agg="last").cells == [[14], [24]]
        # a callable aggregates the raw cell values in task-index order
        assert res.pivot("a", "b", agg=lambda vs: vs[0]).cells == [[10], [20]]

    def test_pivot_agg_unknown_name_raises(self):
        res = self._seeded()
        with pytest.raises(ValueError, match="unknown agg"):
            res.pivot("a", "b", agg="p99")

    def test_to_csv_scalar_and_dict_values(self, tmp_path):
        res = self._results()
        text = res.to_csv(tmp_path / "out.csv")
        lines = text.strip().splitlines()
        assert lines[0] == "i,status,attempts,wall_s,value"
        assert len(lines) == 5
        assert (tmp_path / "out.csv").read_text() == text

        def dicty(ctx):
            return {"loss": ctx["i"] / 2, "acc": 1.0}

        text = Memento(dicty).run(_matrix(2)).to_csv()
        header = text.splitlines()[0]
        assert header == "i,status,attempts,wall_s,loss,acc"


class TestDryRun:
    def test_dry_run_routes_through_task_dry(self):
        hits = []

        def f(ctx):
            hits.append(1)

        prov = RecordingProvider()
        res = Memento(f, prov).run(_matrix(3), dry_run=True)
        assert hits == []
        assert all(r.status == "skipped" for r in res)
        dry = [e for e in prov.events if e.kind == "task_dry"]
        assert len(dry) == 3
        assert all("would run" in e.message for e in dry)
        assert all(e.payload["key"] for e in dry)


_attempt_log: dict[str, list[float]] = {}


def _always_fails_slow_first(ctx: Context):
    """First attempt is the straggler; every attempt fails."""
    log = _attempt_log.setdefault(ctx.key, [])
    log.append(time.time())
    time.sleep(1.2 if len(log) == 1 else 0.3)
    raise RuntimeError(f"attempt {len(log)} fails")


def _fast(ctx: Context):
    return ctx["i"]


class TestAttemptAccounting:
    def test_speculative_twin_failure_counts_against_budget(self):
        """A failed primary whose speculative twin also fails consumes TWO
        attempts of the budget (retries=1 => 2 total), so no third attempt
        is launched."""
        _attempt_log.clear()

        def func(ctx: Context):
            if ctx["i"] == 0:
                return _always_fails_slow_first(ctx)
            return _fast(ctx)

        r = Runner(
            func,
            config=RunnerConfig(
                max_workers=4,
                retries=1,
                enable_speculation=True,
                straggler_min_s=0.25,
                straggler_factor=2.0,
                poll_interval_s=0.02,
            ),
        )
        results = r.run(_matrix(4).task_list())
        by_i = {res.spec.params["i"]: res for res in results}
        assert by_i[0].status == "failed"
        assert by_i[0].attempts == 2
        (executions,) = _attempt_log.values()
        assert len(executions) == 2  # primary + speculative twin, no retry
        assert all(by_i[i].ok for i in (1, 2, 3))

    def test_plain_retries_still_exhaust_budget(self):
        calls = []

        def fails(ctx: Context):
            calls.append(1)
            raise RuntimeError("nope")

        r = Runner(
            fails,
            config=RunnerConfig(max_workers=2, retries=2, enable_speculation=False,
                                retry_backoff_s=0.01),
        )
        results = r.run(_matrix(1).task_list())
        assert results[0].status == "failed"
        assert results[0].attempts == 3
        assert len(calls) == 3


class TestCacheIdentity:
    """Satellite: settings + namespace are part of the cache identity."""

    def test_settings_do_not_cross_hit_cache(self, tmp_path):
        calls = []

        def work(ctx: Context):
            calls.append(ctx.settings["mode"])
            return ctx["i"] * (2 if ctx.settings["mode"] == "double" else 1)

        cache = FsCache(tmp_path / "cache")
        m_plain = ConfigMatrix.from_dict(
            {"parameters": {"i": [1, 2]}, "settings": {"mode": "plain"}}
        )
        m_double = ConfigMatrix.from_dict(
            {"parameters": {"i": [1, 2]}, "settings": {"mode": "double"}}
        )
        eng = Memento(work, cache=cache,
                      runner_config=RunnerConfig(max_workers=1, enable_speculation=False))
        assert eng.run(m_plain).values == [1, 2]
        assert eng.run(m_double).values == [2, 4]  # NOT served from plain's cache
        assert calls == ["plain", "plain", "double", "double"]
        assert eng.run(m_double).values == [2, 4]
        assert len(calls) == 4  # second double run is all cache hits

    def test_namespace_partitions_shared_cache(self, tmp_path):
        def exp_a(ctx: Context):
            return "a"

        def exp_b(ctx: Context):
            return "b"

        cache = FsCache(tmp_path / "cache")
        m = {"parameters": {"i": [1]}}
        ra = Memento(exp_a, cache=cache, namespace="exp-a").run(m)
        rb = Memento(exp_b, cache=cache, namespace="exp-b").run(m)
        assert ra.values == ["a"] and rb.values == ["b"]
        # Same namespace => cache hit; different => isolated.
        assert Memento(exp_b, cache=cache, namespace="exp-a").run(m)[0].status == "cached"
        assert Memento(exp_b, cache=cache, namespace="exp-a").run(m).values == ["a"]


class TestInvalidate:
    """Per-axis cache invalidation: Memento.invalidate(**partial_params)."""

    def test_partial_params_invalidate(self, tmp_path):
        eng = Memento(grid_fn, workdir=tmp_path)
        m = ConfigMatrix.from_dict(
            {"parameters": {"arch": ["a", "b"], "lr": [0.1, 0.2, 0.3]}}
        )
        eng.run(m)
        assert sum(r.status == "cached" for r in eng.run(m)) == 6
        n = eng.invalidate(arch="a")
        assert n == 3, "one axis value matches half the grid"
        res = eng.run(m)
        assert sum(r.status == "cached" for r in res) == 3 and len(res.ok) == 6
        # multi-key partial assignment: exactly one cell
        assert eng.invalidate(arch="b", lr=0.2) == 1
        assert eng.invalidate(arch="zzz") == 0

    def test_invalidate_respects_namespaces(self, tmp_path):
        a = Memento(grid_fn, workdir=tmp_path, namespace="expA")
        b = Memento(grid_fn, workdir=tmp_path, namespace="expB")
        m = ConfigMatrix.from_dict({"parameters": {"arch": ["a"], "lr": [0.1]}})
        a.run(m)
        b.run(m)
        assert a.invalidate(arch="a") == 1
        assert sum(r.status == "cached" for r in b.run(m)) == 1, (
            "expB's entry must survive expA's purge"
        )
        assert b.invalidate() == 1  # no args: the whole namespace

    def test_invalidate_memory_cache(self):
        eng = Memento(grid_fn)  # MemoryCache
        m = ConfigMatrix.from_dict({"parameters": {"arch": ["a", "b"], "lr": [1]}})
        eng.run(m)
        assert eng.invalidate(arch="b") == 1
        assert sum(r.status == "cached" for r in eng.run(m)) == 1


def grid_fn(ctx: Context):
    return f"{ctx['arch']}@{ctx['lr']}"


class TestProgressProvider:
    def test_track_counts_and_eta(self):
        import io

        from repro.core import ProgressNotificationProvider

        buf = io.StringIO()
        eng = Memento(square)
        m = _matrix(4)
        eng.run(m)  # warm the in-memory cache: 4 cached + 0 live on re-run
        prov = ProgressNotificationProvider(total=8, stream=buf)
        results = list(prov.track(eng.stream(_matrix(8))))
        assert len(results) == 8
        assert prov.done == 8 and prov.cached == 4 and prov.failed == 0
        lines = buf.getvalue().strip().splitlines()
        assert len(lines) == 8
        assert "8/8 done" in lines[-1] and "4 cached" in lines[-1]

    def test_event_path_counts_failures(self):
        import io

        from repro.core import ProgressNotificationProvider

        def flaky(ctx: Context):
            if ctx["i"] == 1:
                raise RuntimeError("boom")
            return ctx["i"]

        buf = io.StringIO()
        prov = ProgressNotificationProvider(total=3, stream=buf)
        eng = Memento(
            flaky, notification_provider=prov,
            runner_config=RunnerConfig(max_workers=2, retries=0, enable_speculation=False),
        )
        eng.run(_matrix(3), cache=False)
        assert prov.done == 3 and prov.failed == 1
        assert "1 failed" in buf.getvalue()
