"""Prefix sharing, multi-streamer scheduling, and multi-tenant admission:
the single-streamer gate is gone (concurrent streamers must coexist and the
old two-streamer deadlock must not come back), adopted prefixes stay
greedy-token-identical to the static engine, warm re-submits skip prompt
compute, tenant quotas defer without starving other tenants, weighted-fair
admission follows stride order, and the prefill bucket ladder stays bounded
past the dense cap."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import LeafLayout, init_params
from repro.serve.cache import _graft_leaf
from repro.serve.engine import Engine, ServeConfig
from repro.serve.request import Request, RequestStatus
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx


def _params_for(name):
    cfg = get_config(name).reduced()
    return cfg, init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32) for p in lengths]


def _solo(cfg, params, prompt, max_new):
    eng = Engine(
        cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=max_new, cache_len=64)
    )
    return eng.generate_static({"tokens": np.asarray(prompt)[None, :]}).tokens[0].tolist()


# ==========================================================================
# The deadlock gate is gone: concurrent streamers prefill and complete
# ==========================================================================
class TestConcurrentStreamers:
    @pytest.mark.parametrize("policy", ["swap", "recompute"])
    def test_two_streamers_prefill_concurrently_and_finish(self, policy):
        """Regression for the single-streamer gate. Two reservation-free
        streamers over a pool too small for both worst cases used to
        deadlock (each waiting for the other's unreserved pages); the gate
        serialized them instead. Now both slots must be PREFILLING at once
        at some step, the drain must terminate in bounded steps, and both
        outputs must match solo runs."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [28, 30], seed=11)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=6,
                            chunk_budget=16, preemption=policy),
        )
        rids = [sched.submit(Request(p, max_new_tokens=8)) for p in prompts]
        both_streaming = 0
        for _ in range(500):
            if not (sched.pending or sched.num_active):
                break
            n_prefilling = sum(
                rs.status is RequestStatus.PREFILLING
                for rs in sched._active.values()
            )
            both_streaming = max(both_streaming, n_prefilling)
            sched.step()
        else:
            pytest.fail("two-streamer drain did not terminate in 500 steps")
        assert both_streaming >= 2, (
            "concurrent streamers never coexisted; the single-streamer "
            "gate is effectively back"
        )
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 8)

    def test_chunk_growth_restarts_younger_streamer_only(self):
        """The victim rule that keeps reservation-free multi-streaming
        deadlock-free: among streamers, growth may only restart *younger*
        ones (higher rid) — the oldest streamer always makes progress.
        The restarted streamer replays from chunk zero and still finishes
        token-identically."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [40, 40], seed=4)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16, preemption="recompute",
                            prefix_sharing=False),
        )
        r0 = sched.submit(Request(prompts[0], max_new_tokens=6))
        sched.step()
        r1 = sched.submit(Request(prompts[1], max_new_tokens=6))
        sched.step()
        slots = {rs.rid: slot for slot, rs in sched._active.items()}
        assert all(
            rs.status is RequestStatus.PREFILLING
            for rs in sched._active.values()
        ), "setup: both requests should still be streaming their prompts"
        # the younger streamer may not restart the older one...
        assert not sched._preempt_lru(slots[r1], requester_rid=r1)
        # ...but the older one restarts the youngest above its rid
        assert sched._preempt_lru(slots[r0], requester_rid=r0)
        assert sched.preemptions_total == 1
        assert any(rs.rid == r1 for rs in sched._preempted)
        sched.run()
        for rid, p in zip((r0, r1), prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 6)


# ==========================================================================
# Prefix sharing: token identity, warm adoption, preempt + resume
# ==========================================================================
class TestPrefixSharingIdentity:
    @pytest.mark.parametrize(
        "arch",
        [
            "llama3.2-3b",  # dense GQA, paged: shares
            "recurrentgemma-2b",  # windowed ring pages: sharing no-op
            "deepseek-v2-236b",  # MLA per-slot cache: sharing no-op
            "xlstm-1.3b",  # pure recurrent: sharing no-op
            "llama4-scout-17b-a16e",  # MoE, paged: shares
        ],
    )
    def test_duplicate_prompts_greedy_match_static(self, arch):
        """Duplicate prompts force page adoption (where eligible) and must
        stay token-identical to the lockstep static engine."""
        cfg, params = _params_for(arch)
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=5, cache_len=64, page_size=8,
                        chunk_budget=16, prefix_sharing=True),
        )
        row = np.asarray(
            jax.random.randint(jax.random.PRNGKey(3), (1, 40), 0, cfg.vocab_size)
        )
        batch = {"tokens": np.concatenate([row, row, row])}
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )

    def test_warm_resubmit_adopts_and_skips_chunks(self):
        """A re-submitted prompt adopts its registered pages: fewer prompt
        tokens stream, TTFT work shrinks, tokens stay identical."""
        cfg, params = _params_for("llama3.2-3b")
        (prompt,) = _prompts(cfg, [33], seed=5)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16),
        )
        cold = sched.submit(Request(prompt, max_new_tokens=6))
        sched.run()
        warm = sched.submit(Request(prompt, max_new_tokens=6))
        sched.run()
        rs_cold, rs_warm = sched.result(cold), sched.result(warm)
        assert rs_cold.adopted_tokens == 0
        # 33 tokens @ 8/page: 4 full prompt pages adopted, the 33rd token
        # still streams so the final chunk's logits seed sampling
        assert rs_warm.adopted_tokens == 32
        assert sched.prefix_hits == 1 and sched.prefix_hit_tokens == 32
        assert rs_warm.tokens == rs_cold.tokens == _solo(cfg, params, prompt, 6)

    def test_shared_pages_survive_writer_divergence(self):
        """Two live requests with a common prefix: when the later one
        decodes into its copy, copy-on-write isolates the earlier one;
        both match solo references."""
        cfg, params = _params_for("llama3.2-3b")
        (common,) = _prompts(cfg, [24], seed=8)
        tails = _prompts(cfg, [7, 13], seed=9)
        prompts = [np.concatenate([common, t]).astype(np.int32) for t in tails]
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16),
        )
        r0 = sched.submit(Request(prompts[0], max_new_tokens=8))
        for _ in range(3):  # stream prompt 0 in; its pages get registered
            sched.step()
        r1 = sched.submit(Request(prompts[1], max_new_tokens=8))
        sched.run()
        assert sched.prefix_hits >= 1  # r1 adopted the common prefix pages
        for rid, p in zip((r0, r1), prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 8)

    def test_preempted_then_resumed_with_sharing(self):
        """Sharing on + preemption churn: a preempted-then-resumed request
        (restart re-adopts its own registered pages) stays identical."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [26, 26], seed=13)
        for policy in ("swap", "recompute"):
            sched = Scheduler(
                cfg, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=5,
                                chunk_budget=16, preemption=policy,
                                prefix_sharing=True),
            )
            rids = [sched.submit(Request(p, max_new_tokens=10)) for p in prompts]
            sched.run()
            assert sched.preemptions_total >= 1, policy
            for rid, p in zip(rids, prompts):
                assert sched.result(rid).tokens == _solo(cfg, params, p, 10), (
                    f"divergence under {policy} with sharing on"
                )


# ==========================================================================
# Multi-tenant admission: quotas and weighted-fair ordering
# ==========================================================================
class TestMultiTenant:
    def test_quota_blocked_tenant_does_not_starve_others(self):
        """Tenant A's second request would exceed A's page quota; it defers
        while tenant B admits and finishes. Everything drains, outputs
        stay solo-identical, and the deferral is counted."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [9, 9, 9], seed=7)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16, tenant_quota=3),
        )
        reqs = [
            Request(prompts[0], max_new_tokens=8, tenant="A"),
            Request(prompts[1], max_new_tokens=8, tenant="A"),
            Request(prompts[2], max_new_tokens=8, tenant="B"),
        ]
        rids = [sched.submit(r) for r in reqs]
        sched.run()
        assert sched.quota_deferrals > 0
        # B was admitted while A's second request sat quota-blocked
        assert sched.result(rids[2]).t_admit < sched.result(rids[1]).t_admit
        for rid, r in zip(rids, reqs):
            assert sched.result(rid).tokens == _solo(
                cfg, params, r.prompt, 8
            )

    def test_single_request_over_quota_fails_fast(self):
        cfg, params = _params_for("llama3.2-3b")
        (prompt,) = _prompts(cfg, [9], seed=1)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16, tenant_quota=1),
        )
        sched.submit(Request(prompt, max_new_tokens=30, tenant="A"))
        with pytest.raises(RuntimeError, match="whole quota"):
            sched.run()

    def test_weighted_fair_stride_order(self):
        """Weights {A: 3, B: 1} with one slot and equal-size requests admit
        in stride order A1, B1, A2, A3, A4, B2 — the 3x weight lets A's
        third and fourth requests overtake B's second."""
        cfg, params = _params_for("llama3.2-3b")
        (prompt,) = _prompts(cfg, [8], seed=2)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16,
                            tenant_weights={"A": 3.0, "B": 1.0}),
        )
        order = ["A", "B", "A", "A", "A", "B"]  # submission order
        rids = [
            sched.submit(Request(prompt, max_new_tokens=4, tenant=t))
            for t in order
        ]
        sched.run()
        admitted = sorted(rids, key=lambda r: sched.result(r).t_admit)
        labels = [f"{order[rids.index(r)]}{rids.index(r)}" for r in admitted]
        assert labels == ["A0", "B1", "A2", "A3", "A4", "B5"]


# ==========================================================================
# Prefill bucket ladder stays bounded past the dense cap
# ==========================================================================
class TestBucketCapBoundary:
    def test_past_cap_prompts_use_bounded_pow2_ladder(self):
        """Windowed models legitimately stream prompts past cache_len; the
        bucket for such a length must be a power of two (bounded distinct
        trace count), never the raw length."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, chunk_budget=16),
        )
        sched.cfg = dataclasses.replace(cfg, window_size=32)
        lengths = list(range(65, 700, 3))
        buckets = {sched._bucket_len(n) for n in lengths}
        assert all(b & (b - 1) == 0 for b in buckets), "non-pow2 bucket"
        assert all(sched._bucket_len(n) >= n for n in lengths)
        # log2 ladder: a handful of shapes for hundreds of lengths
        assert len(buckets) <= 4

    def test_past_cap_on_dense_model_fails_loudly(self):
        """A dense model can never legitimately see a past-cap prompt at
        prefill (admission validates); the old code silently returned the
        unbucketed raw length — one fresh compile per prompt."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, chunk_budget=16),
        )
        assert sched._bucket_len(64) == 64
        with pytest.raises(RuntimeError, match="exceeds the dense prefill cap"):
            sched._bucket_len(65)


# ==========================================================================
# Cache graft layout metadata: collisions raise instead of mis-grafting
# ==========================================================================
class TestGraftLayouts:
    def test_dense_graft_longer_source_raises(self):
        """With explicit layout metadata, a dense source longer than the
        target raises instead of being silently ring-folded (the old
        shape-guessing treated any shorter target as a ring)."""
        dst = np.zeros((4, 8, 2), np.float32)
        src = np.ones((4, 12, 2), np.float32)
        lay = LeafLayout("dense", seq_axis=1)
        with pytest.raises(ValueError, match="exceeds target"):
            _graft_leaf(dst, src, prompt_len=12, layout=lay)

    def test_ring_layout_folds_long_source(self):
        """The same shapes graft fine when the layout says ring: the last
        window of the source lands rotated at prompt_len % window."""
        window = 8
        dst = np.zeros((4, window, 2), np.float32)
        src = np.arange(4 * 12 * 2, dtype=np.float32).reshape(4, 12, 2)
        lay = LeafLayout("ring", seq_axis=1, cap=window)
        out = np.asarray(_graft_leaf(dst, src, prompt_len=12, layout=lay))
        # position p lands at ring slot p % window
        for p in range(12 - window, 12):
            np.testing.assert_array_equal(out[:, p % window], src[:, p])

    def test_copy_layout_requires_exact_shape(self):
        dst = np.zeros((4, 8), np.float32)
        src = np.ones((4, 9), np.float32)
        lay = LeafLayout("copy")
        with pytest.raises(ValueError):
            _graft_leaf(dst, src, prompt_len=9, layout=lay)
