"""repro.analysis: metric extraction, comparison tables, perf trajectory,
regression policies, the event-fed dashboard, and the CLI."""
import json
import os
import subprocess
import sys
import urllib.request
from pathlib import Path

import pytest

from repro.analysis import (
    AnalysisNotificationProvider,
    BenchRecord,
    Dashboard,
    Examiner,
    MetricFrame,
    MetricRecord,
    MetricSpec,
    RegressionPolicy,
    Trajectory,
    compare,
    compare_frames,
    detect_regressions,
)
from repro.analysis.trajectory import find_baseline
from repro.core import ConfigMatrix, FileQueue, Memento, RunnerConfig
from repro.core.notifications import Event

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([SRC, env.get("PYTHONPATH", "")])
    return env


def _cli(*args, cwd=None):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=_env(), cwd=cwd,
    )


def _sweep(ctx):
    return {
        "tokens_per_s": 100.0 * ctx["n"] + ctx["seed"],
        "wall_s": 0.5,
        "itl_p50_s": 0.004,
    }


def _run_sweep():
    return Memento(
        _sweep,
        runner_config=RunnerConfig(max_workers=2, enable_speculation=False),
    ).run({"parameters": {"n": [1, 2], "seed": [0, 10]}})


class TestMetrics:
    def test_examine_results_params_and_host_ride_along(self):
        res = _run_sweep()
        frame = Examiner(["tokens_per_s", "wall_s"]).examine_results(res)
        assert len(frame) == 8  # 4 tasks x 2 metrics
        assert set(frame.metrics()) == {"tokens_per_s", "wall_s"}
        r = frame.where(metric="tokens_per_s", n=2, seed=10).records[0]
        assert r.value == 210.0
        assert r.host and r.source == "result"

    def test_spec_extract_and_failed_tasks_skipped(self):
        def sometimes(ctx):
            if ctx["i"] == 1:
                raise RuntimeError("boom")
            return {"itl_p50_s": 0.004 * (ctx["i"] + 1)}

        res = Memento(
            sometimes,
            runner_config=RunnerConfig(max_workers=2, retries=0,
                                       enable_speculation=False),
        ).run({"parameters": {"i": [0, 1, 2]}})
        ex = Examiner([
            MetricSpec("itl_p50_ms", extract=lambda v: v["itl_p50_s"] * 1e3,
                       unit="ms"),
        ])
        frame = ex.examine_results(res)
        assert sorted(frame.values()) == [4.0, 12.0]
        assert all(r.unit == "ms" for r in frame)

    def test_examine_text_regex_num_placeholder(self):
        ex = Examiner({"tok_s": r"({num}) tok/s", "p95_ms": r"p95=({num})ms"})
        frame = ex.examine_text("run A: 42.5 tok/s p95=17ms\nrun B: 99 tok/s")
        assert frame.where(metric="tok_s").values() == [42.5, 99.0]
        assert frame.where(metric="p95_ms").values() == [17.0]

    def test_examine_done_dir(self, tmp_path):
        def f(ctx):
            return ctx["i"]

        eng = Memento(f, workdir=tmp_path / "w")
        eng.run_distributed({"parameters": {"i": [0, 1]}},
                            queue_dir=tmp_path / "q", owner="hostA")
        frame = Examiner(["wall_s", "attempts"]).examine_done_dir(tmp_path / "q")
        assert "failed" in frame.metrics()  # synthetic 0/1 failure metric
        assert set(frame.values("failed")) == {0.0}
        assert all(r.host == "hostA" for r in frame.where(metric="failed"))

    def test_frame_roundtrip_results_csv(self, tmp_path):
        res = _run_sweep()
        path = tmp_path / "r.csv"
        res.to_csv(path)
        frame = MetricFrame.from_results_csv(path)
        assert set(frame.metrics()) == {"wall_s", "tokens_per_s", "itl_p50_s"}
        assert frame.where(metric="tokens_per_s", n=2.0, seed=10.0).values() == [210.0]
        assert frame.param_values("n") == [1.0, 2.0]

    def test_frame_csv_failed_rows_keep_wall_only(self, tmp_path):
        def sometimes(ctx):
            if ctx["i"]:
                raise RuntimeError("boom")
            return {"m": 1.0}

        res = Memento(
            sometimes,
            runner_config=RunnerConfig(retries=0, enable_speculation=False),
        ).run({"parameters": {"i": [0, 1]}})
        path = tmp_path / "r.csv"
        res.to_csv(path)
        frame = MetricFrame.from_results_csv(path)
        assert len(frame.where(metric="m")) == 1
        assert len(frame.where(metric="wall_s")) == 2

    def test_group_and_where_pred(self):
        frame = MetricFrame([
            MetricRecord("m", 1.0, params={"a": 1}, host="h1"),
            MetricRecord("m", 3.0, params={"a": 1}, host="h2"),
            MetricRecord("m", 5.0, params={"a": 2}, host="h1"),
        ])
        assert frame.group(["a"], metric="m") == {(1,): [1.0, 3.0], (2,): [5.0]}
        assert frame.group(["host"]) == {("h1",): [1.0, 5.0], ("h2",): [3.0]}
        assert frame.where(pred=lambda r: r.value > 2).values() == [3.0, 5.0]


class TestTables:
    def _frame(self):
        recs = []
        for a in ("x", "y"):
            for b in (1, 2):
                for rep in range(2):
                    recs.append(MetricRecord(
                        "tok_s", {"x": 10.0, "y": 20.0}[a] * b + rep,
                        params={"arch": a, "slots": b},
                    ))
        return MetricFrame(recs)

    def test_compare_grouped_agg(self):
        t = compare(self._frame(), rows="arch", cols="slots", agg="mean")
        assert t.row_labels == [("x",), ("y",)]
        assert t.col_labels == [1, 2]
        assert t.cells == [[10.5, 20.5], [20.5, 40.5]]

    def test_compare_agg_variants(self):
        t = compare(self._frame(), rows="arch", cols="slots", agg="max")
        assert t.cells[0] == [11.0, 21.0]
        t = compare(self._frame(), rows="arch", cols="slots", agg="count")
        assert t.cells == [[2, 2], [2, 2]]
        t = compare(self._frame(), rows="arch", cols="slots", agg="p95")
        assert t.cells[1][1] == pytest.approx(40.95)

    def test_compare_metrics_as_columns(self):
        frame = MetricFrame([
            MetricRecord("tok_s", 10.0, params={"a": 1}),
            MetricRecord("wall_s", 0.5, params={"a": 1}),
        ])
        t = compare(frame, rows="a")
        assert t.col_labels == ["tok_s", "wall_s"]
        assert t.cells == [[10.0, 0.5]]

    def test_compare_multiple_metrics_with_cols_requires_pick(self):
        frame = MetricFrame([
            MetricRecord("m1", 1.0, params={"a": 1, "b": 1}),
            MetricRecord("m2", 2.0, params={"a": 1, "b": 1}),
        ])
        with pytest.raises(ValueError, match="pass metric="):
            compare(frame, rows="a", cols="b")

    def test_baseline_annotations_in_every_renderer(self):
        t = compare(self._frame(), rows="arch", cols="slots", agg="mean",
                    baseline=1)
        md, csv, txt = t.to_markdown(), t.to_csv(), str(t)
        for out in (md, csv, txt):
            assert "2 (vs 1)" in out
            assert "(1.95x, +95.2%)" in out  # x-row: 10.5 -> 20.5
        assert md.splitlines()[1].startswith("| ---")

    def test_baseline_must_be_a_column(self):
        t = compare(self._frame(), rows="arch", cols="slots")
        t.baseline = 99
        with pytest.raises(ValueError, match="not a column"):
            t.to_markdown()

    def _two_runs(self):
        old = MetricFrame([
            MetricRecord("tok_s", 100.0, params={"benchmark": "B9"}),
            MetricRecord("tok_s", 8.0, params={"benchmark": "B10"}),
        ])
        new = MetricFrame([
            MetricRecord("tok_s", 50.0, params={"benchmark": "B9"}),
        ])
        return old, new

    def test_compare_frames_cross_run_diff(self):
        old, new = self._two_runs()
        t = compare_frames([("base", old), ("cand", new)], rows="benchmark")
        assert t.col_labels == ["base", "cand"]
        assert t.baseline == "base"
        assert t.cells == [[100.0, 50.0], [8.0, None]]
        md = t.to_markdown()
        assert "cand (vs base)" in md
        assert "(0.50x, -50.0%)" in md  # B9 halved
        # B10 is missing from the candidate run: renders as "-", not dropped
        assert "| B10 | 8 | - |" in md

    def test_compare_frames_empty_run_keeps_column(self):
        old, _ = self._two_runs()
        t = compare_frames([("base", old), ("cand", MetricFrame())],
                           rows="benchmark", metric="tok_s")
        assert t.col_labels == ["base", "cand"]
        assert all(row[1] is None for row in t.cells)

    def test_compare_frames_baseline_override_and_agg(self):
        old, new = self._two_runs()
        t = compare_frames({"base": old, "cand": new}, rows="benchmark",
                           baseline="cand", agg="max")
        assert "base (vs cand)" in t.to_markdown()
        assert "(2.00x, +100.0%)" in t.to_markdown()

    def test_compare_frames_validates_inputs(self):
        old, new = self._two_runs()
        with pytest.raises(ValueError, match="at least two"):
            compare_frames([("only", old)], rows="benchmark")
        with pytest.raises(ValueError, match="distinct"):
            compare_frames([("a", old), ("a", new)], rows="benchmark")

    def test_compare_frames_multiple_metrics_requires_pick(self):
        a = MetricFrame([MetricRecord("m1", 1.0, params={"b": 1}),
                         MetricRecord("m2", 2.0, params={"b": 1})])
        b = MetricFrame([MetricRecord("m1", 3.0, params={"b": 1})])
        with pytest.raises(ValueError, match="pass metric="):
            compare_frames([("a", a), ("b", b)], rows="b")
        t = compare_frames([("a", a), ("b", b)], rows="b", metric="m1")
        assert t.cells == [[1.0, 3.0]]


def _write_record(d: Path, n: int, mode: str, commit: str, rows):
    d.mkdir(parents=True, exist_ok=True)
    (d / f"BENCH_{n}.json").write_text(json.dumps({
        "schema": 1, "record": n, "mode": mode, "git_commit": commit,
        "timestamp": f"2026-08-0{min(n, 9)}T00:00:00+00:00", "rows": rows,
    }))


class TestTrajectory:
    def test_load_filter_series(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 10.0}, {"name": "B10", "tok_s": 5.0}])
        _write_record(tmp_path, 2, "full", "c2", [{"name": "B9", "tok_s": 50.0}])
        _write_record(tmp_path, 3, "smoke", "c3", [{"name": "B9", "tok_s": 12.0}])
        traj = Trajectory.load(tmp_path)
        assert [r.record for r in traj] == [1, 2, 3]
        assert traj.modes() == ["smoke", "full"]
        assert traj.filter(mode="smoke").series("B9") == [(1, 10.0), (3, 12.0)]
        assert traj.latest("smoke").record == 3
        assert traj.filter(benchmark="B10").names() == ["B10"]
        frame = traj.to_frame()
        assert frame.where(benchmark="B9", mode="smoke").values() == [10.0, 12.0]

    def test_half_written_and_foreign_files_skipped(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1", [{"name": "B9", "tok_s": 1.0}])
        (tmp_path / "BENCH_2.json").write_text("{ truncated")
        (tmp_path / "BENCH_x.json").write_text("{}")
        assert len(Trajectory.load(tmp_path)) == 1

    def test_find_baseline_prefers_lineage_ancestor(self, tmp_path):
        # record 2 is on a diverged branch; record 1 is an ancestor.
        _write_record(tmp_path, 1, "smoke", "main1", [])
        _write_record(tmp_path, 2, "smoke", "branch", [])
        _write_record(tmp_path, 3, "smoke", "main2", [])
        traj = Trajectory.load(tmp_path)
        lineage = {("main1", "main2"): True, ("branch", "main2"): False}
        base = find_baseline(traj, traj.get(3),
                             is_ancestor=lambda o, n: lineage[(o, n)])
        assert base.record == 1

    def test_find_baseline_fallback_when_lineage_unknowable(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "a", [])
        _write_record(tmp_path, 2, "smoke", "b", [])
        traj = Trajectory.load(tmp_path)
        base = find_baseline(traj, traj.get(2), is_ancestor=lambda o, n: None)
        assert base.record == 1

    def test_find_baseline_none_when_all_diverged_or_other_mode(self, tmp_path):
        _write_record(tmp_path, 1, "full", "a", [])
        _write_record(tmp_path, 2, "smoke", "b", [])
        _write_record(tmp_path, 3, "smoke", "c", [])
        traj = Trajectory.load(tmp_path)
        assert find_baseline(traj, traj.get(3),
                             is_ancestor=lambda o, n: False) is None

    def test_detect_regressions_policy_and_skips(self, tmp_path):
        base_rows = [
            {"name": "B9", "tok_s": 100.0},
            {"name": "B10"},  # no tok_s on the baseline: must be skipped
            {"name": "B11", "tok_s": 0.0},  # zero baseline: skipped
            {"name": "B12", "tok_s": 10.0, "itl_ms": 4.0},
        ]
        new_rows = [
            {"name": "B9", "tok_s": 60.0},  # 0.60x -> flagged
            {"name": "B10", "tok_s": 1.0},
            {"name": "B11", "tok_s": 5.0},
            {"name": "B12", "tok_s": 9.0, "itl_ms": 9.0},  # itl worse 2.25x
        ]
        _write_record(tmp_path, 1, "smoke", "c1", base_rows)
        _write_record(tmp_path, 2, "smoke", "c1", new_rows)
        traj = Trajectory.load(tmp_path)
        regs = detect_regressions(traj.get(2), traj.get(1))
        assert [r.name for r in regs] == ["B9"]
        assert regs[0].warn_line() == (
            "WARN,B9,tok/s 100.0 -> 60.0 (0.60x vs record 1, >30% regression)"
        )
        both = detect_regressions(
            traj.get(2), traj.get(1),
            policies=(RegressionPolicy(),
                      RegressionPolicy("itl_ms", max_drop=0.5,
                                       higher_is_better=False)),
        )
        assert {(r.name, r.metric) for r in both} == {("B9", "tok_s"),
                                                      ("B12", "itl_ms")}

    def test_run_py_diff_delegates_and_matches_cli(self, tmp_path):
        """The harness's WARN lines and the CLI's are identical verdicts."""
        _write_record(tmp_path, 1, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 100.0},
                       {"name": "B10"}])
        _write_record(tmp_path, 2, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 50.0},
                       {"name": "B10", "tok_s": 9.9}])
        import importlib.util

        run_path = Path(__file__).parent.parent / "benchmarks" / "run.py"
        spec = importlib.util.spec_from_file_location("bench_run", run_path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        harness_lines = mod.diff_records(
            str(tmp_path / "BENCH_2.json"), str(tmp_path)
        )
        out = _cli("regressions", "--records-dir", str(tmp_path))
        cli_lines = [ln for ln in out.stdout.splitlines()
                     if ln.startswith("WARN,")]
        assert harness_lines == cli_lines == [
            "WARN,B9,tok/s 100.0 -> 50.0 (0.50x vs record 1, >30% regression)"
        ]

    def test_cli_regressions_strict_gates(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 100.0}])
        _write_record(tmp_path, 2, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 50.0}])
        assert _cli("regressions", "--records-dir", str(tmp_path)).returncode == 0
        strict = _cli("regressions", "--records-dir", str(tmp_path), "--strict")
        assert strict.returncode == 1
        # no regression -> strict passes
        _write_record(tmp_path / "ok", 1, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 100.0}])
        _write_record(tmp_path / "ok", 2, "smoke", "unknown",
                      [{"name": "B9", "tok_s": 95.0}])
        assert _cli("regressions", "--records-dir", str(tmp_path / "ok"),
                    "--strict").returncode == 0


def _event(kind, t=1000.0, **payload):
    return Event(kind=kind, message="", unix_time=t, payload=payload)


class TestDashboardProvider:
    def _feed(self, prov):
        prov.notify(_event("run_started", t=1000.0, total=4, workers=2))
        prov.notify(_event(
            "task_finished", t=1001.0, key="k1", status="ok",
            params={"i": 0}, host="h1", wall_s=1.0, attempts=1, cached=False,
            metrics={"tokens_per_s": 50.0, "generated_tokens": 64.0,
                     "accept_rate": 0.9},
        ))
        prov.notify(_event(
            "task_failed", t=1002.0, key="k2", status="failed",
            params={"i": 1}, host="h2", wall_s=0.5, attempts=2, cached=False,
            error="RuntimeError: boom", traceback="Traceback ... boom",
        ))
        prov.notify(_event(
            "queue_progress", t=1002.5, total=4, done=2, failed=1,
            claimed_by={"h1": 1}, done_by={"h1": 1, "h2": 1},
            owner="h1", elapsed_s=2.5, eta_s=2.5,
        ))

    def test_aggregates_and_failure_drilldown(self):
        prov = AnalysisNotificationProvider()
        self._feed(prov)
        s = prov.state()
        assert s["total"] == 4 and s["done"] == 2 and s["failed"] == 1
        assert s["queue"]["claimed_by"] == {"h1": 1}
        assert set(s["hosts"]) == {"h1", "h2"}
        assert s["hosts"]["h1"]["tokens_per_s"] == 64.0  # 64 tokens / 1.0s
        assert s["hosts"]["h1"]["metrics"]["accept_rate"] == 0.9
        assert s["serve"]["accept_rate"] == 0.9
        [fail] = s["failures"]
        assert fail["error"] == "RuntimeError: boom"
        assert "boom" in fail["traceback"]
        assert fail["host"] == "h2" and fail["params"] == {"i": 1}
        assert s["eta_s"] is not None and s["eta_s"] >= 0

    def test_journal_write_and_replay(self, tmp_path):
        journal = tmp_path / "events.jsonl"
        prov = AnalysisNotificationProvider(journal_path=journal)
        self._feed(prov)
        lines = journal.read_text().strip().splitlines()
        assert len(lines) == 4
        assert json.loads(lines[0])["kind"] == "run_started"

        fresh = AnalysisNotificationProvider()
        offset = fresh.replay_journal(journal)
        assert offset == len(journal.read_bytes())
        assert fresh.state()["done"] == prov.state()["done"]
        assert fresh.state()["failures"] == prov.state()["failures"]
        # replay does not re-append to a journal
        prov2 = AnalysisNotificationProvider(journal_path=journal)
        prov2.replay_journal(journal)
        assert len(journal.read_text().strip().splitlines()) == 4

    def test_events_since_cursor(self):
        prov = AnalysisNotificationProvider()
        self._feed(prov)
        cursor, events = prov.events_since(0)
        assert cursor == 4 and len(events) == 4
        cursor2, tail = prov.events_since(cursor)
        assert cursor2 == 4 and tail == []

    def test_track_and_notify_double_report_deduped(self):
        prov = AnalysisNotificationProvider()
        eng = Memento(
            lambda ctx: {"tokens_per_s": 1.0},
            notification_provider=prov,
            runner_config=RunnerConfig(max_workers=2, enable_speculation=False),
        )
        results = list(prov.track(eng.stream(
            ConfigMatrix.from_dict({"parameters": {"i": [0, 1, 2]}})
        )))
        assert len(results) == 3
        assert prov.state()["done"] == 3  # not 6

    def test_trajectory_payload(self, tmp_path):
        from repro.analysis.dash import trajectory_payload

        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 10.0, "wall_s": 2.0},
                       {"name": "B10", "tok_s": 5.0}])
        _write_record(tmp_path, 2, "smoke", "c2",
                      [{"name": "B9", "tok_s": 12.0, "wall_s": 1.5}])
        t = trajectory_payload(tmp_path)
        assert t["metric"] == "tok_s" and t["records"] == [1, 2]
        assert t["series"]["B9"] == [{"record": 1, "value": 10.0},
                                     {"record": 2, "value": 12.0}]
        assert t["series"]["B10"] == [{"record": 1, "value": 5.0}]
        # metric/benchmark filters
        t = trajectory_payload(tmp_path, metric="wall_s", benchmark="B9")
        assert list(t["series"]) == ["B9"]
        assert [p["value"] for p in t["series"]["B9"]] == [2.0, 1.5]
        # empty dir: valid empty payload, not an error
        assert trajectory_payload(tmp_path / "none")["series"] == {}

    def test_http_trajectory_endpoint(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 10.0}])
        _write_record(tmp_path, 2, "smoke", "c2",
                      [{"name": "B9", "tok_s": 12.0}])
        dash = Dashboard(AnalysisNotificationProvider(),
                         records_dir=tmp_path)
        url = dash.start()
        try:
            with urllib.request.urlopen(f"{url}/api/trajectory",
                                        timeout=5) as r:
                t = json.loads(r.read())
            assert t["series"]["B9"] == [{"record": 1, "value": 10.0},
                                         {"record": 2, "value": 12.0}]
            with urllib.request.urlopen(
                f"{url}/api/trajectory?benchmark=B99", timeout=5
            ) as r:
                assert json.loads(r.read())["series"] == {}
            with urllib.request.urlopen(url, timeout=5) as r:
                page = r.read().decode()
            assert "/api/trajectory" in page and "spark" in page
        finally:
            dash.stop()

    def test_http_endpoints(self):
        prov = AnalysisNotificationProvider()
        self._feed(prov)
        dash = Dashboard(prov)  # port=0: ephemeral
        url = dash.start()
        try:
            with urllib.request.urlopen(f"{url}/api/state", timeout=5) as r:
                state = json.loads(r.read())
            assert state["done"] == 2 and "h1" in state["hosts"]
            with urllib.request.urlopen(f"{url}/api/events?since=0",
                                        timeout=5) as r:
                ev = json.loads(r.read())
            assert ev["next"] == 4 and len(ev["events"]) == 4
            with urllib.request.urlopen(url, timeout=5) as r:
                page = r.read().decode()
            assert "memento fleet" in page and "/api/state" in page
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(f"{url}/api/nope", timeout=5)
        finally:
            dash.stop()


class TestCLI:
    def test_table_cli_identical_to_api(self, tmp_path):
        res = _run_sweep()
        csv_path = tmp_path / "r.csv"
        res.to_csv(csv_path)
        frame = MetricFrame.from_results_csv(csv_path)
        api = compare(frame, rows="n", cols="seed", metric="tokens_per_s",
                      agg="mean", baseline=0).to_markdown()
        out = _cli("table", "--csv", str(csv_path), "--rows", "n",
                   "--cols", "seed", "--metric", "tokens_per_s",
                   "--agg", "mean", "--baseline", "0")
        assert out.returncode == 0, out.stderr
        assert out.stdout.strip() == api

    def test_table_latest_record(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 10.0}])
        out = _cli("table", "--latest", "--records-dir", str(tmp_path))
        assert out.returncode == 0, out.stderr
        assert "| B9 | 10 |" in out.stdout
        assert "Benchmark record 1" in out.stdout

    def test_table_cli_diff_records(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 100.0},
                       {"name": "B10", "tok_s": 8.0}])
        _write_record(tmp_path, 2, "smoke", "c2",
                      [{"name": "B9", "tok_s": 50.0}])
        out = _cli("table", "--diff", "1", "2",
                   "--records-dir", str(tmp_path))
        assert out.returncode == 0, out.stderr
        assert "record 2 (vs record 1)" in out.stdout
        assert "(0.50x, -50.0%)" in out.stdout
        assert "| B10 | 8 | - |" in out.stdout
        # identical to the API, token for token
        traj = Trajectory.load(tmp_path)
        api = compare_frames(
            [(f"record {n}", Trajectory([traj.get(n)]).to_frame())
             for n in (1, 2)],
            rows="benchmark", metric="tok_s",
            title="tok_s: record 1 vs record 2",
        ).to_markdown()
        assert out.stdout.strip() == api

    def test_table_cli_diff_errors(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 1.0}])
        out = _cli("table", "--diff", "1", "7",
                   "--records-dir", str(tmp_path))
        assert out.returncode != 0
        assert "no record 7" in out.stderr
        out = _cli("table", "--diff", "1", "--records-dir", str(tmp_path))
        assert out.returncode != 0
        assert "at least two" in out.stderr
        out = _cli("table", "--diff", "1", "1", "--latest",
                   "--records-dir", str(tmp_path))
        assert out.returncode != 0
        assert "exclusive" in out.stderr

    def test_table_cli_diff_csv_runs(self, tmp_path):
        res = _run_sweep()
        a, b = tmp_path / "a.csv", tmp_path / "b.csv"
        res.to_csv(a)
        res.to_csv(b)
        # CSV runs need --rows (no benchmark param to default to)
        out = _cli("table", "--diff", str(a), str(b))
        assert out.returncode != 0 and "--rows" in out.stderr
        out = _cli("table", "--diff", str(a), str(b), "--rows", "n",
                   "--metric", "tokens_per_s")
        assert out.returncode == 0, out.stderr
        # identical inputs: every diff column is exactly 1.00x
        assert "(1.00x, +0.0%)" in out.stdout
        assert "(vs " in out.stdout

    def test_trajectory_cli_json(self, tmp_path):
        _write_record(tmp_path, 1, "smoke", "c1",
                      [{"name": "B9", "tok_s": 10.0}])
        _write_record(tmp_path, 2, "smoke", "c2",
                      [{"name": "B9", "tok_s": 12.0}])
        out = _cli("trajectory", "--records-dir", str(tmp_path),
                   "--series", "B9", "--json")
        assert out.returncode == 0, out.stderr
        data = json.loads(out.stdout)
        assert data["series"] == [{"record": 1, "value": 10.0},
                                  {"record": 2, "value": 12.0}]

    def test_filequeue_stats_json(self, tmp_path):
        q = FileQueue(tmp_path / "q", owner="me")
        specs = ConfigMatrix.from_dict(
            {"parameters": {"i": [0, 1, 2]}}
        ).task_list()
        q.publish(specs)
        assert q.try_claim(specs[0].key)
        q.mark_done(specs[1].key, "ok", {"wall_s": 0.1})
        out = subprocess.run(
            [sys.executable, "-m", "repro.core.filequeue", "stats",
             str(tmp_path / "q"), "--json"],
            capture_output=True, text=True, env=_env(),
        )
        assert out.returncode == 0, out.stderr
        data = json.loads(out.stdout)
        assert data["total"] == 3 and data["claimed"] == 1
        assert data["done"] == 1 and data["available"] == 1
        assert data["done_by"] == {"me": 1}
        # the human format still works
        out = subprocess.run(
            [sys.executable, "-m", "repro.core.filequeue", "stats",
             str(tmp_path / "q")],
            capture_output=True, text=True, env=_env(),
        )
        assert "total=3 claimed=1 done=1 available=1" in out.stdout
