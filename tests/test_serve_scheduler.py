"""Continuous-batching scheduler: token-identity with the static-batch
engine, independent retirement under staggered admissions, slot reuse,
per-request stop conditions, the no-recompile guarantee for the decode hot
path, and per-slot cache grafting edge cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.cache import graft_states, insert_slot
from repro.serve.engine import Engine, ServeConfig
from repro.serve.request import Request, RequestStatus
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32) for p in lengths]


def _solo_reference(cfg, params, prompt, max_new):
    """Greedy tokens for one request generated alone by the static loop."""
    eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=max_new, cache_len=64))
    return eng.generate_static({"tokens": np.asarray(prompt)[None, :]}).tokens[0].tolist()


class TestSchedulerCorrectness:
    @pytest.mark.parametrize("arch", ["llama3.2-3b", "recurrentgemma-2b", "deepseek-v2-236b"])
    def test_greedy_matches_static_engine(self, arch):
        """Continuous-batching greedy decode == static-batch engine,
        token-for-token, across dense GQA, hybrid window+recurrent, and MLA."""
        cfg = get_config(arch).reduced()
        params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=5, cache_len=64))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7), (2, 9), 0, cfg.vocab_size)}
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )

    def test_staggered_admissions_retire_independently(self, dense_model):
        """Requests submitted mid-flight produce exactly their solo tokens,
        and short requests retire while long ones keep decoding."""
        cfg, params = dense_model
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=2, cache_len=64)
        )
        prompts = _prompts(cfg, [5, 9, 7], seed=1)
        r0 = sched.submit(Request(prompts[0], max_new_tokens=3))
        r1 = sched.submit(Request(prompts[1], max_new_tokens=9))
        for _ in range(4):
            sched.step()
        # r0 (3 tokens) must already be done; r1 still riding.
        assert sched.result(r0).done and sched.result(r0).finish_reason == "length"
        assert sched.num_active == 1
        r2 = sched.submit(Request(prompts[2], max_new_tokens=4))
        while sched.pending or sched.num_active:
            sched.step()
        for rid, prompt in zip((r0, r1, r2), prompts):
            rs = sched.result(rid)
            assert rs.tokens == _solo_reference(
                cfg, params, prompt, rs.request.max_new_tokens
            ), f"request {rid} diverged from its solo run"

    def test_freed_slots_are_reused(self, dense_model):
        cfg, params = dense_model
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=1, cache_len=64)
        )
        rids = [sched.submit(Request(p, max_new_tokens=3)) for p in _prompts(cfg, [4, 6, 5])]
        done = sched.run()
        assert len(done) == 3
        assert all(rs.slot == 0 for rs in done)  # one slot served everyone
        # later tenants of the slot still match their solo runs (no leakage
        # from the previous occupant's cache rows)
        for rs in done:
            assert rs.tokens == _solo_reference(cfg, params, rs.request.prompt, 3)

    def test_stop_token_and_max_new_honored_per_request(self, dense_model):
        cfg, params = dense_model
        [prompt] = _prompts(cfg, [6], seed=3)
        solo = _solo_reference(cfg, params, prompt, 8)
        stop = solo[2]  # force a stop at the 3rd generated token
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=2, cache_len=64)
        )
        r_stop = sched.submit(Request(prompt, max_new_tokens=8, stop_token=stop))
        r_len = sched.submit(Request(prompt, max_new_tokens=8))
        sched.run()
        rs_stop, rs_len = sched.result(r_stop), sched.result(r_len)
        assert rs_stop.finish_reason == "stop" and rs_stop.tokens == solo[:3]
        assert rs_len.finish_reason == "length" and rs_len.tokens == solo

    def test_request_stats_populated(self, dense_model):
        cfg, params = dense_model
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=1, cache_len=64)
        )
        rid = sched.submit(Request(_prompts(cfg, [4])[0], max_new_tokens=4))
        [rs] = sched.run()
        assert rs.rid == rid and rs.status is RequestStatus.FINISHED
        assert rs.t_submit <= rs.t_admit <= rs.t_first_token <= rs.t_finish
        assert rs.ttft_s >= 0 and rs.latency_s > 0 and rs.decode_tokens_per_s > 0


class TestNoRecompile:
    def test_decode_hot_path_single_trace_across_churn(self, dense_model):
        """Requests of different prompt/output lengths joining and leaving
        must not retrigger tracing of the jitted decode step: exactly one
        trace (the warmup) for the whole multi-admission run."""
        cfg, params = dense_model
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=2, cache_len=64)
        )
        prompts = _prompts(cfg, [4, 11, 7, 5], seed=4)
        sched.submit(Request(prompts[0], max_new_tokens=2))
        sched.submit(Request(prompts[1], max_new_tokens=6))
        for _ in range(3):
            sched.step()
        sched.submit(Request(prompts[2], max_new_tokens=4))
        sched.submit(Request(prompts[3], max_new_tokens=3))
        sched.run()
        assert sched.stats()["finished"] == 4
        assert sched.decode_traces == 1, (
            f"decode step retraced {sched.decode_traces}x; "
            "joins/retires must only change array values"
        )


class TestCacheGrafting:
    def test_ring_wrap_prompt_longer_than_window(self):
        """Prompt of length P > window W: slot p % W holds position p for the
        last W positions; earlier positions are evicted."""
        W, P = 8, 13
        dst = jnp.zeros((1, W, 2, 4), jnp.bfloat16)
        src = jnp.arange(1 * P * 2 * 4, dtype=jnp.float32).reshape(1, P, 2, 4)
        out = graft_states(dst, src, P)
        assert out.shape == (1, W, 2, 4) and out.dtype == jnp.bfloat16
        for p in range(P - W, P):
            np.testing.assert_array_equal(
                np.asarray(out[0, p % W], np.float32),
                np.asarray(src[0, p].astype(jnp.bfloat16), np.float32),
            )

    def test_dense_left_align_and_zero_tail(self):
        P, C = 5, 12
        dst = jnp.zeros((1, C, 3), jnp.bfloat16)
        src = jnp.ones((1, P, 3), jnp.float32) * 2.5
        out = graft_states(dst, src, P)
        np.testing.assert_array_equal(np.asarray(out[0, :P], np.float32), 2.5)
        np.testing.assert_array_equal(np.asarray(out[0, P:], np.float32), 0.0)

    def test_dtype_preserved_over_stacked_groups(self):
        """Scan-stacked leaves (leading layer axis) keep the cache dtype."""
        L, P, C = 4, 6, 16
        dst = jnp.zeros((L, 1, C, 2), jnp.bfloat16)
        src = jnp.full((L, 1, P, 2), 1.0 / 3.0, jnp.float32)
        out = graft_states(dst, src, P)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(out[:, :, :P]), np.asarray(src.astype(jnp.bfloat16))
        )

    def test_insert_slot_targets_one_batch_row(self):
        full = jnp.zeros((3, 16, 2))
        one = jnp.ones((1, 16, 2))
        out = insert_slot(full, one, jnp.asarray(1))
        np.testing.assert_array_equal(np.asarray(out)[1], 1.0)
        np.testing.assert_array_equal(np.asarray(out)[[0, 2]], 0.0)

    def test_insert_slot_stacked_groups_batch_axis(self):
        """With a leading scan axis the batch axis is axis 1 — located by
        shape, not by convention."""
        full = jnp.zeros((4, 3, 16))
        one = jnp.full((4, 1, 16), 7.0)
        out = insert_slot(full, one, jnp.asarray(2))
        np.testing.assert_array_equal(np.asarray(out[:, 2]), 7.0)
        np.testing.assert_array_equal(np.asarray(out[:, :2]), 0.0)

    def test_ring_wrap_end_to_end_generation(self):
        """Windowed arch with prompt > window: scheduler == static engine."""
        cfg = get_config("recurrentgemma-2b").reduced()
        assert cfg.window_size and cfg.window_size < 40
        params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=4, cache_len=64))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 40), 0, cfg.vocab_size)
        }
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )
