"""Paged KV serving: PagePool alloc/free invariants (property tests),
paged-vs-static greedy token identity across the model zoo's state
families, pool-capacity admission backpressure, shape-stable decode under
page growth, bucketed-prefill compile counts, and the paged decode kernel
vs its XLA gather reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.cache import graft_pages_leaf
from repro.serve.engine import Engine, ServeConfig
from repro.serve.pages import PageLayout, PagePool, model_page_span
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx


def _params_for(name):
    cfg = get_config(name).reduced()
    return cfg, init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32) for p in lengths]


# ==========================================================================
# PagePool invariants
# ==========================================================================
class TestPagePoolProperties:
    @settings(max_examples=30)
    @given(
        n_pages=st.integers(min_value=1, max_value=40),
        page_size=st.integers(min_value=1, max_value=32),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    def test_random_admit_retire_no_alias_no_leak(self, n_pages, page_size, seed):
        """Under random reserve/grow/release traffic: a page is never held
        by two slots, reservations are never overcommitted, and releasing
        everything returns the pool to fully free."""
        layout = PageLayout(page_size=page_size, n_pages=n_pages, span=n_pages * page_size)
        pool = PagePool(layout)
        rng = np.random.default_rng(seed)
        live: dict[int, int] = {}  # slot -> reserved count
        next_slot = 0
        for _ in range(200):
            op = rng.integers(0, 3)
            if op == 0:  # admit
                want = int(rng.integers(1, max(n_pages // 2, 2)))
                if pool.can_reserve(want):
                    pool.reserve(next_slot, want)
                    pool.grow_to(next_slot, int(rng.integers(0, want + 1)))
                    live[next_slot] = want
                    next_slot += 1
            elif op == 1 and live:  # grow an existing slot within reservation
                slot = int(rng.choice(list(live)))
                pool.grow_to(slot, int(rng.integers(0, live[slot] + 1)))
            elif op == 2 and live:  # retire
                slot = int(rng.choice(list(live)))
                pool.release(slot)
                del live[slot]
            # no-alias: every allocated page id is unique across slots
            held = [p for s in live for p in pool.allocated(s)]
            assert len(held) == len(set(held)), "page aliased across slots"
            # no-leak: free + allocated partitions the pool exactly
            assert pool.n_free + len(held) == n_pages
            # reservations stay backed: growth can never fail
            assert pool.available() >= 0
        for slot in list(live):
            pool.release(slot)
        assert pool.n_free == n_pages and pool.in_use == 0

    def test_overcommit_and_overgrow_rejected(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=4, span=16))
        pool.reserve(0, 3)
        assert not pool.can_reserve(2)  # only 1 page unbacked
        with pytest.raises(RuntimeError):
            pool.reserve(1, 2)
        pool.reserve(1, 1)
        with pytest.raises(RuntimeError):
            pool.grow_to(1, 2)  # beyond its reservation
        pool.release(0)
        assert pool.can_reserve(3)

    def test_pages_for_len_ring_folds(self):
        layout = PageLayout(page_size=8, n_pages=16, span=32)  # e.g. window 32
        assert layout.pages_for_len(0) == 0
        assert layout.pages_for_len(1) == 1
        assert layout.pages_for_len(8) == 1
        assert layout.pages_for_len(9) == 2
        assert layout.pages_for_len(32) == 4
        assert layout.pages_for_len(500) == 4  # ring reuse, bounded set
        assert layout.max_pages == 4 and layout.trash == 16


# ==========================================================================
# Refcounted sharing + copy-on-write invariants
# ==========================================================================
def _chain(prompt_id: int, n: int) -> list[bytes]:
    """A deterministic prefix-key chain standing in for prefix_page_keys."""
    return [f"p{prompt_id}-{j}".encode() for j in range(n)]


class TestRefcountCoWProperties:
    def _check(self, pool, live):
        """The module-docstring invariants, recomputed from scratch."""
        n_pages = pool.layout.n_pages
        # conservation: free + cached + distinct-in-use partitions the pool
        assert pool.n_free + pool.n_cached + pool.in_use == n_pages
        # refcount == number of slot table entries mapping the page, and a
        # page reaches the free/cached sets only at refcount zero
        counts: dict[int, int] = {}
        for s in live:
            for pid in pool.allocated(s):
                counts[pid] = counts.get(pid, 0) + 1
        assert counts == {pid: pool.refcount(pid) for pid in counts}
        assert all(r > 0 for r in counts.values())
        # the incremental owed-backing counter equals the recomputed sum
        assert pool._owed == pool.owed_recomputed()
        assert pool.available() >= 0

    @settings(max_examples=30)
    @given(
        n_pages=st.integers(min_value=2, max_value=32),
        page_size=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    def test_random_share_cow_traffic(self, n_pages, page_size, seed):
        """Random adopt/grow/register/prepare_write/release traffic keeps
        refcounts exact, conserves pages, keeps the incremental owed
        counter equal to its recomputation, and leaves written ranges
        exclusively owned."""
        layout = PageLayout(
            page_size=page_size, n_pages=n_pages, span=n_pages * page_size
        )
        pool = PagePool(layout)
        rng = np.random.default_rng(seed)
        live: dict[int, list[bytes]] = {}  # slot -> its prompt key chain
        next_slot = 0
        for _ in range(150):
            op = rng.integers(0, 4)
            if op == 0:  # admit, scheduler-style: pre-check worst case,
                # reserve nothing, adopt the indexed prefix run, extend
                keys = _chain(int(rng.integers(0, 3)), int(rng.integers(1, 5)))
                if pool.can_reserve(len(keys)):
                    pool.reserve(next_slot, 0)
                    adopted = pool.adopt_prefix(next_slot, keys)
                    target = int(rng.integers(adopted, len(keys) + 1))
                    assert pool.extend_to(next_slot, target)
                    live[next_slot] = keys
                    next_slot += 1
            elif op == 1 and live:  # grow + register full pages
                slot = int(rng.choice(list(live)))
                held = len(pool.allocated(slot))
                target = int(rng.integers(held, pool._reserved[slot] + 1))
                pool.grow_to(slot, target)
                for j in range(len(pool.allocated(slot))):
                    if j < len(live[slot]) and rng.integers(0, 2):
                        pool.register_page(slot, j, live[slot][j])
            elif op == 2 and live:  # write a random token range: CoW
                slot = int(rng.choice(list(live)))
                held = pool.allocated(slot)
                if held:
                    start = int(rng.integers(0, len(held) * page_size))
                    stop = int(rng.integers(start + 1, len(held) * page_size + 1))
                    need = {j for j in range(start // page_size,
                                             (stop - 1) // page_size + 1)}
                    shared = sum(
                        1 for j in need if pool.refcount(held[j]) > 1
                    )
                    # Forks are unreserved allocations: only fork within the
                    # unreserved headroom, as scheduler traffic does (writes
                    # land past the adopted span, so shared forks are rare).
                    if shared <= pool.available():
                        pool.prepare_write(slot, start, stop)
                        held = pool.allocated(slot)
                        for j in need:
                            # written pages are exclusively owned + unindexed
                            assert pool.refcount(held[j]) == 1
                            assert held[j] not in pool._key_of
            elif op == 3 and live:  # retire
                slot = int(rng.choice(list(live)))
                pool.release(slot)
                del live[slot]
            self._check(pool, live)
        for slot in list(live):
            pool.release(slot)
        self._check(pool, {})
        # cached pages are recyclable: taking everything drains the pool
        assert pool.n_free + pool.n_cached == n_pages

    def test_adopt_longest_indexed_run_and_revival(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=8, span=32))
        keys = _chain(0, 4)
        pool.reserve(0, 0)
        assert pool.extend_to(0, 3) and pool.grow_to(0, 3)
        for j in range(3):
            pool.register_page(0, j, keys[j])
        pool.release(0)  # refcount zero -> indexed pages park in cached LRU
        assert pool.n_cached == 3 and pool.in_use == 0
        pool.reserve(1, 0)
        assert pool.adopt_prefix(1, keys) == 3  # keys[3] unindexed: run stops
        assert pool.n_cached == 0 and pool.in_use == 3
        assert [pool.refcount(p) for p in pool.allocated(1)] == [1, 1, 1]
        # adoption raised reservation with allocation: owed unchanged
        assert pool._owed == 0 == pool.owed_recomputed()

    def test_register_first_wins(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=8, span=32))
        key = _chain(7, 1)[0]
        for slot in (0, 1):
            pool.reserve(slot, 1)
            pool.grow_to(slot, 1)
        assert pool.register_page(0, 0, key)
        assert not pool.register_page(1, 0, key)  # concurrent same prompt
        assert not pool.register_page(0, 0, key)  # idempotent
        pool.reserve(2, 0)
        assert pool.adopt_prefix(2, [key]) == 1
        assert pool.allocated(2) == pool.allocated(0) != pool.allocated(1)

    def test_shared_write_always_forks(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=8, span=32))
        keys = _chain(0, 2)
        pool.reserve(0, 2)
        pool.grow_to(0, 2)
        for j in range(2):
            pool.register_page(0, j, keys[j])
        pool.reserve(1, 0)
        assert pool.adopt_prefix(1, keys) == 2
        shared = list(pool.allocated(1))
        assert shared == pool.allocated(0)
        forks = pool.prepare_write(1, 0, 5)  # touches pages 0 and 1
        assert [(j, old) for j, old, _ in forks] == [(0, shared[0]), (1, shared[1])]
        assert pool.allocated(1) != pool.allocated(0)
        assert all(pool.refcount(p) == 1 for p in pool.allocated(0))
        assert all(pool.refcount(p) == 1 for p in pool.allocated(1))
        assert pool.cow_forks == 2
        # owner's copies stay indexed; a third adopter still hits them
        pool.reserve(2, 0)
        assert pool.adopt_prefix(2, keys) == 2
        assert pool.allocated(2) == pool.allocated(0)

    def test_release_frees_only_at_refcount_zero(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=4, span=16))
        key = _chain(0, 1)[0]
        pool.reserve(0, 1)
        pool.grow_to(0, 1)
        pool.register_page(0, 0, key)
        pool.reserve(1, 0)
        assert pool.adopt_prefix(1, [key]) == 1
        pid = pool.allocated(0)[0]
        pool.release(0)
        assert pool.refcount(pid) == 1 and pool.n_cached == 0  # still held by 1
        pool.release(1)
        assert pool.refcount(pid) == 0 and pool.n_cached == 1  # parked, indexed

    def test_cached_lru_eviction_unindexes(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=2, span=8))
        keys = _chain(0, 2)
        pool.reserve(0, 2)
        pool.grow_to(0, 2)
        for j in range(2):
            pool.register_page(0, j, keys[j])
        pool.release(0)
        assert pool.n_cached == 2 and pool.n_free == 0
        # a fresh private allocation must evict the LRU cached page
        pool.reserve(1, 1)
        pool.grow_to(1, 1)
        assert pool.cache_evictions == 1
        pool.reserve(2, 0)
        # the evicted (oldest) page left the index; the newer one survives
        assert pool.adopt_prefix(2, keys) == 0  # chain broken at keys[0]
        assert keys[0] not in pool._index and keys[1] in pool._index


# ==========================================================================
# Token identity: paged scheduler vs static engine, across state families
# ==========================================================================
class TestPagedTokenIdentity:
    @pytest.mark.parametrize(
        "arch",
        [
            "llama3.2-3b",  # dense GQA
            "recurrentgemma-2b",  # windowed ring KV + recurrent hybrid
            "deepseek-v2-236b",  # MLA (per-slot path behind same interface)
            "xlstm-1.3b",  # pure recurrent: zero pages
            "llama4-scout-17b-a16e",  # MoE, scan-stacked groups
        ],
    )
    def test_greedy_paged_matches_static(self, arch):
        cfg, params = _params_for(arch)
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=5, cache_len=64, page_size=8),
        )
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(7), (3, 9), 0, cfg.vocab_size)
        }
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )

    def test_paged_matches_contiguous_scheduler(self):
        """Same requests through a paged and a contiguous scheduler produce
        identical greedy tokens (the pool is an invisible layout change)."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [5, 11, 7, 9], seed=2)
        outs = []
        for paged in (True, False):
            sched = Scheduler(
                cfg, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=2, cache_len=64, paged=paged, page_size=8),
            )
            for p in prompts:
                sched.submit(Request(p, max_new_tokens=6))
            outs.append([rs.tokens for rs in sched.run()])
        assert outs[0] == outs[1]

    def test_ring_window_prompt_longer_than_window_paged(self):
        """Windowed arch, prompt > window: ring-folded pages match static."""
        cfg, params = _params_for("recurrentgemma-2b")
        assert cfg.window_size and cfg.window_size < 40
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=4, cache_len=64, page_size=8),
        )
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 40), 0, cfg.vocab_size)
        }
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )


# ==========================================================================
# Admission against pool capacity (OOM backpressure)
# ==========================================================================
class TestPoolBackpressure:
    def test_small_pool_defers_admission_and_stays_correct(self):
        """A pool too small for two worst-case requests serializes them:
        free slots alone don't admit, results still match solo runs."""
        cfg, params = _params_for("llama3.2-3b")
        page = 8
        # Each request worst-cases at ceil((9 + 8)/8) = 3 pages; pool of 4
        # pages fits one at a time even though 2 slots are free.
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=page, n_pages=4),
        )
        prompts = _prompts(cfg, [9, 9], seed=3)
        r0 = sched.submit(Request(prompts[0], max_new_tokens=8))
        r1 = sched.submit(Request(prompts[1], max_new_tokens=8))
        sched.step()
        assert sched.num_active == 1 and sched.pending == 1, (
            "second request must defer on pool capacity, not slot count"
        )
        assert sched.stats()["deferred_admissions"] > 0
        sched.run()
        solo = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=8, cache_len=64, page_size=page),
        )
        for rid, p in ((r0, prompts[0]), (r1, prompts[1])):
            expect = solo.generate_static({"tokens": p[None, :]}).tokens[0].tolist()
            assert sched.result(rid).tokens == expect

    def test_never_admissible_request_fails_fast(self):
        """A request whose worst case exceeds the whole pool must raise a
        clear error instead of deferring forever (run() would spin)."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8, n_pages=2),
        )
        sched.submit(Request(_prompts(cfg, [20])[0], max_new_tokens=8))
        with pytest.raises(RuntimeError, match="pool has only 2"):
            sched.run()

    def test_zero_page_models_skip_pool(self):
        """Pure-recurrent models need no pages; the paged config degrades to
        the per-slot path with no pool at all."""
        cfg, params = _params_for("xlstm-1.3b")
        assert model_page_span(cfg, 64) == 0
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, paged=True),
        )
        assert sched.pool is None
        sched.submit(Request(_prompts(cfg, [6])[0], max_new_tokens=3))
        [rs] = sched.run()
        assert len(rs.tokens) == 3


# ==========================================================================
# Shape stability + compile counts
# ==========================================================================
class TestPagedNoRecompile:
    def test_single_decode_trace_across_churn_and_page_growth(self):
        """Joins, retirements, and page-table growth (decode crossing page
        boundaries) must never retrace the decode step: the page table is a
        fixed-shape int32 array whose values change."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            # page_size 4: every request crosses several page boundaries
            SchedulerConfig(n_slots=2, cache_len=64, page_size=4),
        )
        prompts = _prompts(cfg, [4, 11, 7, 5], seed=4)
        sched.submit(Request(prompts[0], max_new_tokens=6))
        sched.submit(Request(prompts[1], max_new_tokens=9))
        for _ in range(3):
            sched.step()
        sched.submit(Request(prompts[2], max_new_tokens=7))
        sched.submit(Request(prompts[3], max_new_tokens=3))
        sched.run()
        assert sched.stats()["finished"] == 4
        assert sched.decode_traces == 1, (
            f"decode step retraced {sched.decode_traces}x; joins/retires/"
            "page-growth must only change array values"
        )
        # Growth actually happened: some slot ended holding > 1 page worth.
        assert sched.pool.peak_in_use >= 3

    def test_prefill_buckets_bound_compiles(self):
        """Many distinct prompt lengths inside one power-of-two bucket must
        share a single prefill and a single admit compilation."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, min_bucket=16),
        )
        assert sched._bucketed
        for p in _prompts(cfg, [9, 10, 11, 12, 13, 16], seed=5):  # all -> bucket 16
            sched.submit(Request(p, max_new_tokens=2))
        sched.run()
        assert sched.prefill_traces == 1, sched.prefill_traces
        assert sched.admit_traces == 1, sched.admit_traces
        sched.submit(Request(_prompts(cfg, [17], seed=6)[0], max_new_tokens=2))
        sched.run()  # next bucket: exactly one more of each
        assert sched.prefill_traces == 2 and sched.admit_traces == 2

    def test_buckets_disabled_for_recurrent_models(self):
        """Recurrent states would absorb pad tokens; bucketing auto-disables
        and prefill compiles per exact length (correctness over compiles)."""
        cfg, params = _params_for("recurrentgemma-2b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=1, cache_len=64)
        )
        assert not sched._bucketed


# ==========================================================================
# Scheduler stats & result retention (satellite)
# ==========================================================================
class TestStatsAndEviction:
    def test_cumulative_stats_survive_eviction(self):
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, keep_finished=2),
        )
        rids = [
            sched.submit(Request(p, max_new_tokens=3))
            for p in _prompts(cfg, [4, 5, 6, 7, 8], seed=7)
        ]
        results = sched.run()
        st_ = sched.stats()
        assert st_["finished"] == 5, "cumulative count must survive eviction"
        assert st_["generated_tokens"] == sum(len(r.tokens) for r in results) == 15
        assert st_["retained"] == 2
        # Oldest results were evicted: clear error, not a bare KeyError.
        with pytest.raises(KeyError, match="evicted \\(keep_finished=2\\)"):
            sched.result(rids[0])
        sched.result(rids[-1])  # newest still retained
        with pytest.raises(KeyError, match="unknown request id"):
            sched.result(99)

    def test_result_of_inflight_request(self):
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=1, cache_len=64)
        )
        rid = sched.submit(Request(_prompts(cfg, [4])[0], max_new_tokens=8))
        sched.step()
        with pytest.raises(KeyError, match="not finished"):
            sched.result(rid)
        sched.run()
        assert sched.result(rid).done


# ==========================================================================
# Paged graft + paged decode kernel vs reference
# ==========================================================================
class TestPagedGraftAndKernel:
    def test_graft_pages_dense_left_align(self):
        P1, page, S = 5, 4, 6
        pool = jnp.zeros((P1, page, 2, 3), jnp.bfloat16)
        src = jnp.arange(S * 2 * 3, dtype=jnp.float32).reshape(1, S, 2, 3) + 1.0
        ids = jnp.asarray([2, 0, 4, 4], jnp.int32)  # 2 real pages, trash-padded
        out = graft_pages_leaf(pool, src, ids, S, cap=16, page_size=page)
        got = np.concatenate([np.asarray(out[2], np.float32), np.asarray(out[0], np.float32)])
        np.testing.assert_array_equal(got[:S], np.asarray(src[0].astype(jnp.bfloat16), np.float32))
        np.testing.assert_array_equal(got[S:], 0.0)

    def test_graft_pages_ring_fold_with_traced_len(self):
        """Windowed leaf, prompt > window: last W positions land at p % W,
        and a traced prompt_len produces the same pages as a static one."""
        W, page, S = 8, 4, 13
        pool = jnp.zeros((4, page, 2, 1), jnp.float32)
        src = jnp.arange(S * 2, dtype=jnp.float32).reshape(1, S, 2, 1)
        ids = jnp.asarray([1, 2, 3, 3], jnp.int32)
        static = graft_pages_leaf(pool, src, ids, S, cap=W, page_size=page)
        traced = jax.jit(
            lambda pl_, s_, n: graft_pages_leaf(pl_, s_, ids, n, cap=W, page_size=page)
        )(pool, src, jnp.asarray(S, jnp.int32))
        np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))
        ring = np.concatenate([np.asarray(static[1]), np.asarray(static[2])])
        for p in range(S - W, S):
            np.testing.assert_array_equal(ring[p % W], np.asarray(src[0, p]))

    @pytest.mark.parametrize("window", [0, 13, 16])
    def test_paged_kernel_matches_gather_reference(self, window):
        from repro.kernels import ops

        rng = np.random.default_rng(0)
        B, KV, G, D, page, P, MP = 3, 2, 4, 16, 8, 10, 4
        kp = jnp.asarray(rng.normal(size=(P + 1, page, KV, D)).astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(P + 1, page, KV, D)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(B, 1, KV * G, D)).astype(np.float32))
        pt = np.full((B, MP), P, np.int32)  # trash-padded tables
        pt[0, :3] = [0, 1, 2]
        pt[1, :2] = [3, 4]
        pt[2, :4] = [5, 6, 7, 8]
        cur = jnp.asarray([17, 9, 30], jnp.int32)
        n_lp = MP if not window else -(-window // page)

        o = ops.paged_decode_attention_op(
            q, kp, vp, jnp.asarray(pt), cur, n_lp=n_lp, window=window
        )

        # XLA reference: materialise the gather, mask by analytic positions.
        T = MP * page
        kg = kp[jnp.asarray(pt)].reshape(B, T, KV, D)
        vg = vp[jnp.asarray(pt)].reshape(B, T, KV, D)
        kb = jnp.broadcast_to(kg[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, KV * G, D)
        vb = jnp.broadcast_to(vg[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, KV * G, D)
        idx = jnp.arange(T)
        if window:
            k_pos = cur[:, None] - ((cur[:, None] - idx[None, :]) % window)
            k_pos = jnp.where(idx[None, :] < window, k_pos, -1)
        else:
            k_pos = jnp.broadcast_to(idx[None, :], (B, T))
        s = jnp.einsum("bhd,bthd->bht", q.reshape(B, KV * G, D), kb) * (D ** -0.5)
        valid = (k_pos <= cur[:, None]) & (k_pos >= 0)
        if window:
            valid = valid & (k_pos > cur[:, None] - window)
        s = jnp.where(valid[:, None, :], s, -1e30)
        ref = jnp.einsum("bht,bthe->bhe", jax.nn.softmax(s, -1), vb).reshape(B, 1, KV * G, D)
        err = float(jnp.max(jnp.abs(o - ref)))
        assert err < 2e-5, err

    def test_pallas_backend_end_to_end_paged_decode(self):
        """attn_backend=pallas routes paged decode through the kernel; greedy
        tokens must match the XLA gather path."""
        from dataclasses import replace

        cfg, params = _params_for("llama3.2-3b")
        toks = []
        for backend in ("xla", "pallas"):
            c = replace(cfg, attn_backend=backend)
            sched = Scheduler(
                c, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=2, cache_len=64, page_size=16),
            )
            for p in _prompts(cfg, [7, 12], seed=8):
                sched.submit(Request(p, max_new_tokens=4))
            toks.append([rs.tokens for rs in sched.run()])
        assert toks[0] == toks[1]
