"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + finiteness. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models import lm
from repro.models.schema import count_params, init_params
from repro.sharding.rules import ShardingCtx

ARCHS = list_archs()


def tiny_batch(cfg, B=2, S=32, seed=1):
    key = jax.random.PRNGKey(seed)
    tok_len = S - cfg.prefix_len if cfg.prefix_len else S
    batch = {
        "tokens": jax.random.randint(key, (B, tok_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, tok_len), 0, cfg.vocab_size),
    }
    if cfg.prefix_len:
        batch["prefix_embeds"] = (
            jax.random.normal(key, (B, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.1
        )
    if cfg.enc_dec:
        batch["enc_embeds"] = (
            jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.1
        )
    return batch


@pytest.fixture(scope="module")
def built():
    """Params + batch per arch, built once."""
    out = {}
    for name in ARCHS:
        cfg = get_config(name).reduced()
        schema = lm.model_schema(cfg)
        params = init_params(schema, jax.random.PRNGKey(0))
        out[name] = (cfg, schema, params, tiny_batch(cfg))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    n = count_params(lm.model_schema(cfg))
    # Sanity bands on total parameter counts (x2 tolerance on nameplates).
    expected = {
        "xlstm-1.3b": (0.8e9, 3e9),
        "llama3.2-3b": (2e9, 6e9),
        "qwen3-8b": (5e9, 12e9),
        "qwen2.5-14b": (10e9, 20e9),
        "mistral-large-123b": (90e9, 160e9),
        "whisper-tiny": (20e6, 90e6),
        "paligemma-3b": (1.5e9, 5e9),
        "llama4-scout-17b-a16e": (60e9, 140e9),
        "deepseek-v2-236b": (150e9, 300e9),
        "recurrentgemma-2b": (1.5e9, 5e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(built, arch):
    cfg, schema, params, batch = built[arch]
    sctx = ShardingCtx.null()
    loss, metrics = jax.jit(lambda p, b: lm.forward_train(p, cfg, b, sctx))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss={loss}"
    assert metrics["tokens"] > 0
    # loss should be near ln(vocab) at init (random labels)
    import math

    assert 0.3 * math.log(cfg.vocab_size) < float(metrics["xent"]) < 3 * math.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_gradients_finite(built, arch):
    cfg, schema, params, batch = built[arch]
    sctx = ShardingCtx.null()
    grads = jax.jit(
        jax.grad(lambda p, b: lm.forward_train(p, cfg, b, sctx)[0])
    )(params, batch)
    bad = [
        k
        for k, g in enumerate(jax.tree.leaves(grads))
        if not bool(jnp.all(jnp.isfinite(g)))
    ]
    assert not bad, f"{arch}: non-finite grads at leaves {bad[:5]}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(built, arch):
    cfg, schema, params, batch = built[arch]
    sctx = ShardingCtx.null()
    B = batch["tokens"].shape[0]
    logits, states = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))(params, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    logits2, states2 = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))(
        params, states, tok
    )
    assert logits2.shape == logits.shape
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(states2["pos"]) == int(states["pos"]) + 1
