"""Hypothesis fallback so tier-1 collects and runs everywhere.

When ``hypothesis`` is installed it is re-exported untouched. When it is
absent (minimal CI images), ``given``/``settings``/``st`` degrade to a
deterministic example-based harness: each strategy is a seeded sampler and
``@given`` expands to a loop over ``max_examples`` pseudo-random examples.
That keeps the property tests meaningful (many diverse examples, stable
across runs) without the shrinking/database machinery.

Usage in test modules::

    from _compat import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    import string

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A deterministic sampler: ``draw(rng)`` returns one example."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=-(2**31), max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def text(alphabet=string.ascii_letters + string.digits, min_size=0, max_size=10):
            chars = list(alphabet)

            def draw(rng):
                n = rng.randint(min_size, max_size)
                return "".join(rng.choice(chars) for _ in range(n))

            return _Strategy(draw)

        @staticmethod
        def dictionaries(keys, values, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = {}
                # Oversample: duplicate keys collapse, mirroring hypothesis.
                for _ in range(n * 3):
                    if len(out) >= n:
                        break
                    out[keys.draw(rng)] = values.draw(rng)
                return out

            return _Strategy(draw)

    st = _Strategies()

    _PENDING_SETTINGS: dict[str, int] = {}

    def settings(max_examples: int = 20, **_kw):
        """Records max_examples for the @given applied to the same function.

        Works in either decorator order because @given reads the marker off
        the wrapped function, and @settings applied on top of the @given
        wrapper stores it where the loop can see it.
        """

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # Positional strategies fill the rightmost parameters (hypothesis
            # semantics); resolve them to names so drawn values are passed by
            # keyword and can never collide with fixture arguments.
            sig = inspect.signature(fn)
            param_names = list(sig.parameters)
            pos_names = param_names[len(param_names) - len(arg_strategies):] if arg_strategies else []
            strategies = dict(zip(pos_names, arg_strategies)) | kw_strategies

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", None) or getattr(
                    fn, "_compat_max_examples", 20
                )
                # Seed from the test name: stable across runs and processes.
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"example {i + 1}/{n} failed for {drawn}: {e}"
                        ) from e

            # Strip the strategy-bound parameters from the visible signature
            # so pytest does not treat them as fixtures.
            params = [p for p in sig.parameters.values() if p.name not in strategies]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=params)
            return wrapper

        return deco
