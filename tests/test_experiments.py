"""Workload adapters: serve/train sweeps running *through* the Memento core.

The acceptance scenario for the v2 experiment API: a 2-model x 2-backend
serving sweep driven by ``experiments.serve_sweep`` inherits caching — the
second run executes nothing and is served entirely from cache.
"""
import numpy as np
import pytest

import repro.core as memento
from repro.experiments import serve_matrix, serve_sweep, train_matrix, train_sweep

ARCHS = ["llama3.2-3b", "recurrentgemma-2b"]
BACKENDS = ["xla", "pallas"]


def _runner_config():
    return memento.RunnerConfig(max_workers=1, retries=0, enable_speculation=False)


class TestServeSweep:
    @pytest.fixture(scope="class")
    def sweep_runs(self, tmp_path_factory):
        workdir = tmp_path_factory.mktemp("serve-sweep")
        matrix = serve_matrix(
            ARCHS,
            backends=BACKENDS,
            scheduler={"n_slots": [2]},
            cache_len=64,
            n_requests=2,
            prompt_lens=(5, 9),
            max_new_tokens=3,
            warmup=False,
        )
        eng = memento.Memento(
            serve_sweep,
            memento.RecordingProvider(),
            workdir=workdir,
            namespace="serve",
            runner_config=_runner_config(),
        )
        first = eng.run(matrix)
        second = eng.run(matrix)
        return first, second

    def test_two_by_two_sweep_runs_through_memento(self, sweep_runs):
        first, _ = sweep_runs
        assert len(first) == len(ARCHS) * len(BACKENDS)
        assert [r.status for r in first] == ["ok"] * 4
        combos = {(r.value["arch"], r.value["attn_backend"]) for r in first}
        assert combos == {(a, b) for a in ARCHS for b in BACKENDS}
        for r in first:
            v = r.value
            assert v["generated_tokens"] == 2 * 3  # n_requests x max_new_tokens
            assert v["decode_traces"] == 1  # hot path compiled once per task
            assert v["tokens_per_s"] > 0

    def test_second_run_served_entirely_from_cache(self, sweep_runs):
        first, second = sweep_runs
        assert [r.status for r in second] == ["cached"] * 4
        # Cached values are the real run's values, keyed identically.
        for a, b in zip(first, second):
            assert a.spec.key == b.spec.key
            assert a.value["tokens"] == b.value["tokens"]

    def test_backends_token_identical(self, sweep_runs):
        """Greedy decode: the pallas kernel path must match XLA per arch."""
        first, _ = sweep_runs
        by_combo = {(r.value["arch"], r.value["attn_backend"]): r.value for r in first}
        for arch in ARCHS:
            assert by_combo[arch, "xla"]["tokens"] == by_combo[arch, "pallas"]["tokens"]

    def test_sweep_composes_with_matrix_algebra(self):
        m = serve_matrix(ARCHS, backends=BACKENDS, n_requests=2) * {
            "parameters": {"paged": [True, False]}
        }
        tasks = m.task_list()
        assert len(tasks) == 8
        assert {t.params["paged"] for t in tasks} == {True, False}

    def test_serve_sweep_distributed_across_hosts(self, tmp_path):
        """The ROADMAP item: a serve sweep drained through the file-queue.
        One 'host' executes the cells; a second host (same shared workdir +
        queue) assembles the identical full ResultSet without re-running —
        everything arrives via the shared cache / done records."""
        from repro.experiments import serve_sweep_distributed

        matrix = serve_matrix(
            ["llama3.2-3b"], backends=["xla"], scheduler={"n_slots": [2]},
            cache_len=64, n_requests=2, prompt_lens=(5, 9), max_new_tokens=3,
            warmup=False,
        )
        first = serve_sweep_distributed(
            matrix, queue_dir=tmp_path / "q", workdir=tmp_path / "w",
            owner="host-a",
        )
        assert [r.status for r in first] == ["ok"]
        assert first[0].value["generated_tokens"] == 2 * 3
        second = serve_sweep_distributed(
            matrix, queue_dir=tmp_path / "q", workdir=tmp_path / "w",
            owner="host-b",
        )
        assert [r.status for r in second] == ["cached"]
        assert second[0].value["tokens"] == first[0].value["tokens"]


class TestTrainSweep:
    def test_train_sweep_through_memento_and_cache(self, tmp_path):
        matrix = train_matrix(
            ["llama3.2-3b"], lrs=[1e-3], steps=4, seq_len=16, global_batch=2,
            ckpt_every=100, log_every=2, workdir=str(tmp_path / "ckpts"),
        )
        eng = memento.Memento(
            train_sweep,
            workdir=tmp_path / "memento",
            namespace="train",
            runner_config=_runner_config(),
        )
        first = eng.run(matrix)
        assert [r.status for r in first] == ["ok"]
        v = first[0].value
        assert np.isfinite(v["loss_first"]) and np.isfinite(v["loss_last"])
        assert v["steps"] == 4
        second = eng.run(matrix)
        assert [r.status for r in second] == ["cached"]
        assert second[0].value["loss_last"] == v["loss_last"]

    def test_namespaces_partition_a_shared_workdir(self, tmp_path):
        # serve and train sweeps can share one workdir without key collisions
        # even if their matrices coincide (the namespace splits them).
        m = {"parameters": {"arch": ["llama3.2-3b"]}}
        ka = memento.as_matrix(m).task_list(namespace="serve")[0].key
        kb = memento.as_matrix(m).task_list(namespace="train")[0].key
        assert ka != kb
