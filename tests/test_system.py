"""End-to-end behaviour: Memento orchestrating real JAX training tasks —
the paper's Fig. 1 workflow at miniature scale, including the
fail -> fix code -> rerun-from-cache loop."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.core import ConsoleNotificationProvider, Memento, RecordingProvider, RunnerConfig
from repro.data.pipeline import DataConfig
from repro.sharding.rules import ShardingCtx
from repro.train.loop import TrainRunConfig, train_run
from repro.train.optimizer import AdamWConfig, Schedule

_BROKEN = {"enabled": True}


def train_task(ctx):
    """One (lr x arch) hyperparameter cell: a real (tiny) training run."""
    if _BROKEN["enabled"] and ctx["lr"] == 3e-3:
        raise RuntimeError("simulated bug in the high-lr branch")
    cfg = get_config(ctx["arch"]).reduced()
    shape = ShapeConfig("tiny", "train", seq_len=32, global_batch=4)
    run = TrainRunConfig(
        steps=6, ckpt_every=100, log_every=3,
        ckpt_dir=str(ctx.settings["workdir"]) + f"/ckpt-{ctx.key[:8]}",
        opt=AdamWConfig(schedule=Schedule(base_lr=ctx["lr"], warmup_steps=2, kind="const")),
        data=DataConfig(seed=0, vocab_size=cfg.vocab_size),
    )
    res = train_run(cfg, shape, ShardingCtx.null(), run, ctx=ctx)
    return {"loss_last": res["loss_last"], "lr": ctx["lr"]}


def test_memento_orchestrates_training_with_failure_and_fix(tmp_path):
    matrix = {
        "parameters": {"arch": ["llama3.2-3b"], "lr": [1e-3, 3e-3]},
        "settings": {"workdir": str(tmp_path)},
    }
    prov = RecordingProvider()
    eng = Memento(
        train_task, prov, workdir=tmp_path / "memento",
        runner_config=RunnerConfig(max_workers=1, retries=0, enable_speculation=False),
    )
    # First run: one task fails (the simulated bug), one succeeds + caches.
    _BROKEN["enabled"] = True
    res1 = eng.run(matrix)
    assert len(res1.failed) == 1 and len(res1.ok) == 1
    assert "simulated bug" in res1.failed[0].error

    # "Fix the code" and rerun: the good task comes from cache (no recompute),
    # only the fixed task executes.
    _BROKEN["enabled"] = False
    res2 = eng.run(matrix)
    assert len(res2.failed) == 0
    statuses = {r.spec.params["lr"]: r.status for r in res2}
    assert statuses[1e-3] == "cached"
    assert statuses[3e-3] == "ok"
    assert all(r.value["loss_last"] is not None for r in res2)


def test_dryrun_sweep_matrix_shape():
    """The 40-cell assignment sweep is a well-formed Memento matrix."""
    from repro.launch.dryrun import sweep_matrix
    from repro.core import ConfigMatrix

    m = ConfigMatrix.from_dict(sweep_matrix([False]))
    tasks = m.task_list()
    # 10 archs x 4 shapes = 40 raw; 8 long_500k cells excluded per assignment
    assert m.cartesian_size == 40
    assert len(tasks) == 32
    long_archs = {t.params["arch"] for t in tasks if t.params["shape"] == "long_500k"}
    assert long_archs == {"xlstm-1.3b", "recurrentgemma-2b"}
