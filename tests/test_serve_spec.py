"""Speculative decoding on the chunk-step substrate: drafter units, greedy
token identity across the model zoo's state families, accept/rollback
invariants (exact state restoration on rejection, accepted <= drafted, page
pool conservation), composition with preemption and prefix sharing, bounded
verify compiles, and stop tokens landing mid-draft."""
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _compat import given, settings, st  # noqa: E402

from repro.configs.registry import get_config  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.schema import init_params  # noqa: E402
from repro.serve.draft import (  # noqa: E402
    Drafter,
    NgramDrafter,
    ReplayDrafter,
    ScriptDrafter,
)
from repro.serve.engine import Engine, ServeConfig  # noqa: E402
from repro.serve.request import Request  # noqa: E402
from repro.serve.scheduler import Scheduler, SchedulerConfig  # noqa: E402
from repro.sharding.rules import ShardingCtx  # noqa: E402


def _params_for(name):
    cfg = get_config(name).reduced()
    return cfg, init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))


def _patterned(cfg, length, period=7, seed=0):
    """A prompt with short-range repetition so the n-gram drafter fires."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, size=period).astype(np.int32)
    return np.tile(pat, length // period + 1)[:length]


def _solo(cfg, params, prompt, max_new, stop_token=-1):
    sched = Scheduler(
        cfg, params, ShardingCtx.null(),
        SchedulerConfig(n_slots=1, cache_len=64, page_size=8, chunk_budget=16),
    )
    rid = sched.submit(Request(prompt, max_new_tokens=max_new, stop_token=stop_token))
    sched.run()
    return sched.result(rid).tokens


def _rs(sched, rid):
    """The live RequestState for ``rid``, finished or in flight."""
    import itertools

    for rs in itertools.chain(
        sched._active.values(), sched._queue, sched._preempted
    ):
        if rs.rid == rid:
            return rs
    return sched.result(rid)


def _pool_conserved(sched):
    pool = sched.pool
    n = pool.layout.n_pages if hasattr(pool, "layout") else None
    if n is None:
        n = len(pool._free) + len(pool._cached) + len(pool._ref)
    assert len(pool._free) + len(pool._cached) + len(pool._ref) == n
    assert pool.owed_recomputed() == pool._owed
    return True


# ==========================================================================
# Drafter units
# ==========================================================================
class TestDrafters:
    def test_ngram_proposes_periodic_continuation(self):
        ctx = np.array([1, 2, 3, 1, 2, 3, 1, 2], np.int32)
        d = NgramDrafter()
        assert d.propose(ctx, 3).tolist() == [3, 1, 2]

    def test_ngram_no_match_is_empty(self):
        d = NgramDrafter()
        assert d.propose(np.arange(10, dtype=np.int32), 4).size == 0

    def test_ngram_short_context(self):
        d = NgramDrafter()
        assert d.propose(np.array([5], np.int32), 4).size == 0

    def test_replay_prefix_match_and_miss(self):
        d = ReplayDrafter([np.array([5, 6, 7, 8, 9], np.int32)])
        assert d.propose(np.array([5, 6, 7], np.int32), 2).tolist() == [8, 9]
        assert d.propose(np.array([5, 6, 7], np.int32), 9).tolist() == [8, 9]
        assert d.propose(np.array([4, 6, 7], np.int32), 2).size == 0

    def test_script_pops_in_order_then_empty(self):
        d = ScriptDrafter([np.array([1, 2], np.int32), np.array([3], np.int32)])
        ctx = np.zeros(4, np.int32)
        assert d.propose(ctx, 4).tolist() == [1, 2]
        assert d.propose(ctx, 4).tolist() == [3]
        assert d.propose(ctx, 4).size == 0
        assert d.calls == 3


class _MultiOracleNoise(Drafter):
    """Proposes the true continuation of the matching sequence with the tail
    corrupted after ``n_correct`` tokens (popped per call, 0 when exhausted)
    — drives the verify pass to exactly chosen accept lengths."""

    def __init__(self, seqs, n_correct):
        self.seqs = [np.asarray(s, np.int64) for s in seqs]
        self.n_correct = list(n_correct)

    def propose(self, context, k):
        ctx = np.asarray(context, np.int64)
        L = len(ctx)
        for full in self.seqs:
            if L < len(full) and np.array_equal(full[:L], ctx):
                cont = full[L : L + k].astype(np.int32).copy()
                nc = self.n_correct.pop(0) if self.n_correct else 0
                # out-of-vocab sentinel: never equals a greedy token, forces
                # rejection at exactly position nc
                cont[min(nc, len(cont)):] = -2
                return cont
        return np.zeros((0,), np.int32)


# ==========================================================================
# Token identity: speculative decode vs static reference, across families
# ==========================================================================
class TestSpecTokenIdentity:
    @pytest.mark.parametrize(
        "arch",
        [
            "llama3.2-3b",  # dense GQA, paged
            "recurrentgemma-2b",  # windowed ring KV + RG-LRU (replay rollback)
            "deepseek-v2-236b",  # MLA compressed cache (per-slot path)
            "xlstm-1.3b",  # pure recurrent, zero pages (replay rollback)
            "llama4-scout-17b-a16e",  # MoE, scan-stacked groups
        ],
    )
    def test_spec_greedy_matches_static(self, arch):
        """Whatever the drafter proposes, greedy acceptance emits exactly the
        sequential-decode tokens — asserted against the static engine on
        every cache family. The drafter is an oracle with a corrupted tail
        (accept lengths cycling 0..3), so full accepts, partial accepts, and
        full rejections — including the recurrent/windowed replay rollback —
        all fire on every arch."""
        cfg, params = _params_for(arch)
        batch = {"tokens": np.stack([
            _patterned(cfg, 33, period=5, seed=1),
            _patterned(cfg, 33, period=3, seed=2),
        ])}
        ref = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=6, cache_len=64, page_size=8,
                        chunk_budget=16),
        ).generate_static(batch)
        seqs = [
            np.concatenate([batch["tokens"][i], ref.tokens[i]]) for i in range(2)
        ]
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=6, cache_len=64, page_size=8,
                        chunk_budget=16, speculative=True, draft_k=4,
                        drafter=_MultiOracleNoise(seqs, [0, 1, 2, 3] * 8)),
        )
        np.testing.assert_array_equal(eng.generate(batch).tokens, ref.tokens)
        sched = eng._schedulers[2]
        assert sched.drafted_tokens_total > 0, "speculation never fired"
        assert sched.accepted_tokens_total <= sched.drafted_tokens_total


# ==========================================================================
# Accept/rollback invariants
# ==========================================================================
class TestAcceptRollback:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg, params = _params_for("llama3.2-3b")
        prompt = _patterned(cfg, 12, seed=4)
        solo = _solo(cfg, params, prompt, 12)
        return cfg, params, prompt, solo

    def test_full_rejection_restores_state_exactly(self, setup):
        """A fully rejected draft must leave the scheduler in the identical
        host-visible state as a plain decode step: same tokens, same cached
        position, same page table and refcounts."""
        cfg, params, prompt, _ = setup

        def build(spec):
            s = Scheduler(
                cfg, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                                chunk_budget=16, speculative=spec, draft_k=4),
            )
            rid = s.submit(Request(prompt, max_new_tokens=8))
            s.step()  # prefill + first token
            return s, rid

        spec, rid_s = build(True)
        plain, rid_p = build(False)
        spec.set_drafter(ScriptDrafter([np.full(4, -2, np.int32)]))
        spec.step()
        plain.step()
        assert spec.total_spec_steps == 1
        assert spec.accepted_tokens_total == 0
        rs, rp = _rs(spec, rid_s), _rs(plain, rid_p)
        assert rs.tokens == rp.tokens, "rejected step emitted wrong tokens"
        assert spec._pos_host.tolist() == plain._pos_host.tolist()
        assert dict(spec.pool._ref) == dict(plain.pool._ref)
        assert spec.pool._allocated == plain.pool._allocated
        np.testing.assert_array_equal(spec._pt, plain._pt)
        assert _pool_conserved(spec)
        # and the run still finishes token-identically
        spec.run(), plain.run()
        assert spec.result(rid_s).tokens == plain.result(rid_p).tokens

    @settings(max_examples=8, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=4), min_size=1, max_size=12))
    def test_partial_accepts_preserve_identity_and_pool(self, setup, n_correct):
        """For every per-step accept length the drafter can force, the run
        stays token-identical to the reference and the page pool conserves
        pages at every step boundary."""
        cfg, params, prompt, solo = setup
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True, draft_k=4),
        )
        sched.set_drafter(
            _MultiOracleNoise([np.concatenate([prompt, solo])], n_correct)
        )
        rid = sched.submit(Request(prompt, max_new_tokens=12))
        while not _rs(sched, rid).done:
            sched.step()
            assert _pool_conserved(sched)
            rs = _rs(sched, rid)
            if rs.tokens:
                assert rs.tokens == solo[: len(rs.tokens)]
        assert sched.result(rid).tokens == solo
        assert sched.accepted_tokens_total <= sched.drafted_tokens_total
        assert len(sched.pool._ref) == 0, "finished run must free all pages"

    def test_budget_clamps_drafts_near_max_new(self, setup):
        """Near max_new_tokens the draft window shrinks so a spec step can
        never overshoot the token budget."""
        cfg, params, prompt, solo = setup
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True, draft_k=8),
        )
        sched.set_drafter(ReplayDrafter([np.concatenate([prompt, solo])]))
        rid = sched.submit(Request(prompt, max_new_tokens=5))
        sched.run()
        assert sched.result(rid).tokens == solo[:5]

    def test_sampling_requests_never_speculate(self, setup):
        cfg, params, prompt, _ = setup
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True),
        )
        rid = sched.submit(Request(prompt, max_new_tokens=6, temperature=0.8))
        sched.run()
        assert sched.total_spec_steps == 0
        assert len(sched.result(rid).tokens) == 6


# ==========================================================================
# Composition: preemption, prefix sharing, bounded compiles, stop tokens
# ==========================================================================
class TestSpecComposition:
    @pytest.mark.parametrize("policy", ["swap", "recompute"])
    def test_preempt_resume_mid_speculation(self, policy):
        """A pool too small for both live footprints preempts mid-decode
        while speculation is active; victims resume token-identical."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = [_patterned(cfg, 24, seed=3), _patterned(cfg, 30, seed=5)]
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=8,
                            chunk_budget=16, preemption=policy,
                            speculative=True, draft_k=4),
        )
        rids = [sched.submit(Request(p, max_new_tokens=12)) for p in prompts]
        sched.run()
        assert sched.preemptions_total > 0, "workload must actually preempt"
        assert sched.drafted_tokens_total > 0, "speculation never fired"
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 12), (
                f"request {rid} diverged under {policy} preemption"
            )

    def test_spec_with_prefix_sharing(self):
        """Adopted prefix pages are CoW-protected from verify writes: two
        requests sharing a prompt prefix both match the reference with
        speculation on."""
        cfg, params = _params_for("llama3.2-3b")
        shared = _patterned(cfg, 16, seed=8)
        prompts = [
            np.concatenate([shared, _patterned(cfg, 8, seed=s)]) for s in (11, 12)
        ]
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16, prefix_sharing=True,
                            speculative=True, draft_k=4),
        )
        # primer registers the shared prefix pages
        primer = sched.submit(Request(shared, max_new_tokens=1))
        sched.run()
        assert sched.result(primer).done
        # oracle drafts force accepted multi-token verify writes right next
        # to (and CoW-guarded away from) the adopted prefix pages
        sched.set_drafter(
            ReplayDrafter(
                [np.concatenate([p, _solo(cfg, params, p, 8)]) for p in prompts]
            )
        )
        rids = [sched.submit(Request(p, max_new_tokens=8)) for p in prompts]
        sched.run()
        assert sched.prefix_hits > 0, "workload must adopt shared pages"
        assert sched.drafted_tokens_total > 0
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 8)

    def test_verify_traces_bounded(self):
        """One verify compile per (k-bucket, page-bucket): many requests with
        wildly varying draft lengths stay within the pow2 ladder."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True, draft_k=4),
        )
        for s in range(6):
            sched.submit(Request(_patterned(cfg, 9 + 5 * s, seed=s),
                                 max_new_tokens=10))
        sched.run()
        assert sched.drafted_tokens_total > 0
        # k+1 in [2..5] -> buckets {2, 4, 8}; pages bucket to <= 2 values
        assert sched.verify_traces <= 6, (
            f"verify compiled {sched.verify_traces} traces — unbounded"
        )

    def test_stop_token_mid_draft(self):
        """When the stop token lands inside an accepted run, emission must
        halt at it exactly — trailing accepted tokens are discarded."""
        cfg, params = _params_for("llama3.2-3b")
        prompt = _patterned(cfg, 12, seed=4)
        ref = _solo(cfg, params, prompt, 10)
        stop = ref[4]
        ref_stop = _solo(cfg, params, prompt, 10, stop_token=stop)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True, draft_k=6),
        )
        sched.set_drafter(ReplayDrafter([np.concatenate([prompt, ref])]))
        rid = sched.submit(Request(prompt, max_new_tokens=10, stop_token=stop))
        sched.run()
        assert sched.result(rid).tokens == ref_stop
        assert sched.result(rid).finish_reason == "stop"
        assert sched.accepted_tokens_total > 0, "oracle draft must accept"

    def test_stats_surface_spec_counters(self):
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=1, cache_len=64, page_size=8,
                            chunk_budget=16, speculative=True, draft_k=4),
        )
        rid = sched.submit(Request(_patterned(cfg, 15, seed=2), max_new_tokens=8))
        sched.run()
        st_ = sched.stats()
        for key in ("spec_steps", "spec_replays", "spec_fallbacks",
                    "drafted_tokens", "accepted_tokens", "verify_traces"):
            assert key in st_
        assert st_["drafted_tokens"] >= st_["accepted_tokens"]
        assert sched.result(rid).drafted >= sched.result(rid).accepted
