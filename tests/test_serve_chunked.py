"""Unified token-budget serving step: chunked-prefill token identity with
the static engine across the model zoo's state families, decode-not-stalled
scheduling behavior, bounded chunk-bucket compiles, page-aware preemption
(swap and recompute) with greedy identity for preempted-then-resumed
requests, and reservation-free pool accounting."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.serve.pages import PageLayout, PagePool
from repro.serve.request import Request, RequestStatus
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx


def _params_for(name):
    cfg = get_config(name).reduced()
    return cfg, init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32) for p in lengths]


def _solo(cfg, params, prompt, max_new):
    eng = Engine(
        cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=max_new, cache_len=64)
    )
    return eng.generate_static({"tokens": np.asarray(prompt)[None, :]}).tokens[0].tolist()


# ==========================================================================
# Token identity: chunked streaming vs static engine, across state families
# ==========================================================================
class TestChunkedTokenIdentity:
    @pytest.mark.parametrize(
        "arch",
        [
            "llama3.2-3b",  # dense GQA, paged
            "recurrentgemma-2b",  # windowed ring KV + RG-LRU hybrid
            "deepseek-v2-236b",  # MLA compressed cache (per-slot path)
            "xlstm-1.3b",  # pure recurrent (mLSTM + sLSTM), zero pages
            "llama4-scout-17b-a16e",  # MoE, scan-stacked groups
        ],
    )
    def test_chunked_greedy_matches_static(self, arch):
        """Prompts longer than the chunk budget (and, for the hybrid, than
        the attention window) stream in over several steps and must stay
        token-identical to the lockstep reference."""
        cfg, params = _params_for(arch)
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=5, cache_len=64, page_size=8, chunk_budget=16),
        )
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(7), (2, 40), 0, cfg.vocab_size)
        }
        np.testing.assert_array_equal(
            eng.generate(batch).tokens, eng.generate_static(batch).tokens
        )

    def test_chunked_matches_unchunked_scheduler(self):
        """The unified step is a scheduling change only: same requests,
        chunked and whole-prompt schedulers, identical greedy tokens."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [5, 23, 40, 11], seed=2)
        outs = []
        for budget in (None, 16):
            sched = Scheduler(
                cfg, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=2, cache_len=64, page_size=8, chunk_budget=budget),
            )
            for p in prompts:
                sched.submit(Request(p, max_new_tokens=6))
            outs.append([rs.tokens for rs in sched.run()])
        assert outs[0] == outs[1]


# ==========================================================================
# Scheduling behavior: decode rides while long prompts stream in
# ==========================================================================
class TestUnifiedStep:
    def test_decode_not_stalled_by_long_prefill(self):
        """A long prompt admitted mid-flight streams in chunk by chunk while
        the in-flight request keeps emitting one token per step."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, chunk_budget=16),
        )
        short, long_ = _prompts(cfg, [4, 48], seed=3)
        r_short = sched.submit(Request(short, max_new_tokens=10))
        sched.step()  # 4-token prompt fits one chunk: joins decode at once
        rs_short = next(rs for rs in sched._active.values() if rs.rid == r_short)
        assert rs_short.status is RequestStatus.ACTIVE
        n0 = len(rs_short.tokens)
        r_long = sched.submit(Request(long_, max_new_tokens=4))
        # Three steps stream the 48-token prompt (3 chunks of 16); the short
        # request must collect one token per step throughout.
        for _ in range(3):
            sched.step()
        rs_long = next(rs for rs in sched._active.values() if rs.rid == r_long)
        assert len(rs_short.tokens) == n0 + 3, (
            "in-flight decode stalled behind a streaming prefill"
        )
        assert rs_long.chunk_pos == 48, "long prompt should be fully streamed"
        sched.run()

    def test_prefilling_state_survives_decode_churn(self):
        """A PREFILLING slot's half-streamed state must not be perturbed by
        other slots' decode steps (recurrences would absorb the masked
        slot's garbage token) — asserted end-to-end via token identity on
        the recurrent hybrid."""
        cfg, params = _params_for("recurrentgemma-2b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, chunk_budget=16),
        )
        prompts = _prompts(cfg, [6, 40], seed=4)
        rids = [sched.submit(Request(p, max_new_tokens=6)) for p in prompts]
        sched.run()
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 6)


# ==========================================================================
# Compile counts
# ==========================================================================
class TestChunkCompileCounts:
    def test_bounded_traces_per_chunk_and_page_bucket(self):
        """Chunk shapes are (token bucket, page bucket) pairs — both
        power-of-two — so streaming prompts of many lengths compiles a
        bounded set of chunk programs, the decode step exactly once, and a
        repeat of the same workload compiles nothing new."""
        cfg, params = _params_for("llama3.2-3b")
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=128, page_size=8,
                            chunk_budget=32, min_chunk=8),
        )
        # Token buckets {8, 16, 32} x page buckets {1, 2, 4, 8}: at most 12
        # shapes, far fewer than the 6 distinct lengths x cursor positions.
        lengths = [40, 19, 55, 9, 33, 24]
        for p in _prompts(cfg, lengths, seed=5):
            sched.submit(Request(p, max_new_tokens=3))
        sched.run()
        assert sched.stats()["finished"] == 6
        assert sched.decode_traces == 1, sched.decode_traces
        assert sched.chunk_traces <= 12, (
            f"chunk program traced {sched.chunk_traces}x for <= 12 buckets"
        )
        assert sched.prefill_traces == 0, "chunked requests must not run prefill"
        # Steady state: the same length mix re-traces nothing.
        before = sched.chunk_traces
        for p in _prompts(cfg, lengths, seed=6):
            sched.submit(Request(p, max_new_tokens=3))
        sched.run()
        assert sched.chunk_traces == before, "steady-state workload retraced"
        assert sched.decode_traces == 1

    def test_chunk_budget_validation(self):
        cfg, params = _params_for("llama3.2-3b")
        with pytest.raises(ValueError, match="chunk_budget"):
            Scheduler(
                cfg, params, ShardingCtx.null(),
                SchedulerConfig(chunk_budget=8, min_chunk=16),
            )
        with pytest.raises(ValueError, match="preemption"):
            Scheduler(
                cfg, params, ShardingCtx.null(), SchedulerConfig(preemption="swap")
            )


# ==========================================================================
# Page-aware preemption
# ==========================================================================
class TestPreemption:
    @pytest.mark.parametrize("policy", ["swap", "recompute"])
    def test_preempted_requests_resume_token_identical(self, policy):
        """A pool too small for two requests' live footprints forces
        preemption mid-decode; the victim resumes and its final tokens are
        exactly its solo run's."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [24, 30], seed=3)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=8,
                            chunk_budget=16, preemption=policy),
        )
        rids = [sched.submit(Request(p, max_new_tokens=12)) for p in prompts]
        sched.run()
        assert sched.preemptions_total > 0, "workload must actually preempt"
        assert sched.decode_traces == 1
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 12), (
                f"request {rid} diverged after {policy} preemption"
            )

    def test_swap_snapshot_roundtrips_recurrent_state(self):
        """Swap preemption on the windowed+recurrent hybrid: the snapshot
        carries ring pages AND per-slot recurrence states verbatim."""
        cfg, params = _params_for("recurrentgemma-2b")
        prompts = _prompts(cfg, [20, 26], seed=6)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=5,
                            chunk_budget=16, preemption="swap"),
        )
        rids = [sched.submit(Request(p, max_new_tokens=10)) for p in prompts]
        sched.run()
        assert sched.preemptions_total > 0
        for rid, p in zip(rids, prompts):
            assert sched.result(rid).tokens == _solo(cfg, params, p, 10)

    def test_decoder_self_preempts_when_streamer_pins_pool(self):
        """Decode-side growth never victimizes a streamer (only a chunk
        request may restart a *younger* streamer); when a streamer has
        pinned the pool and a decoder crosses a page boundary, the decoder
        parks *itself* (instead of crashing) and resumes token-identically."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [6, 24], seed=9)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=4,
                            chunk_budget=16, preemption="swap"),
        )
        r0 = sched.submit(Request(prompts[0], max_new_tokens=12))
        sched.step()  # r0 streams its 1-chunk prompt and starts decoding
        r1 = sched.submit(Request(prompts[1], max_new_tokens=4))
        sched.run()
        assert sched.preemptions_total >= 1
        for rid, p, max_new in ((r0, prompts[0], 12), (r1, prompts[1], 4)):
            assert sched.result(rid).tokens == _solo(cfg, params, p, max_new)

    def test_reservation_free_admission_overcommits_pool(self):
        """With preemption on, admission no longer reserves the worst case:
        two requests whose combined worst case exceeds the pool are both
        admitted (the off policy would defer the second)."""
        cfg, params = _params_for("llama3.2-3b")
        prompts = _prompts(cfg, [9, 9], seed=3)
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=2, cache_len=64, page_size=8, n_pages=4,
                            chunk_budget=16, preemption="recompute"),
        )
        for p in prompts:
            sched.submit(Request(p, max_new_tokens=8))
        for _ in range(3):
            sched.step()
        assert sched.num_active == 2, (
            "reservation-free admission must not defer on worst-case capacity"
        )
        sched.run()
        assert sched.finished_total == 2


# ==========================================================================
# Paged chunked-prefill kernel vs XLA gather reference
# ==========================================================================
class TestPagedChunkKernel:
    def test_kernel_matches_gather_reference(self):
        import jax.numpy as jnp

        from repro.kernels import ops

        rng = np.random.default_rng(0)
        B, KV, G, D, page, P, MP, C = 2, 2, 3, 16, 8, 9, 4, 8
        kp = jnp.asarray(rng.normal(size=(P + 1, page, KV, D)).astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(P + 1, page, KV, D)).astype(np.float32))
        q = jnp.asarray(rng.normal(size=(B, C, KV * G, D)).astype(np.float32))
        pt = np.full((B, MP), P, np.int32)
        pt[0, :3] = [0, 1, 2]
        pt[1, :4] = [3, 4, 5, 6]
        start = jnp.asarray([10, 17], jnp.int32)  # chunks mid-prompt

        o = ops.paged_chunk_attention_op(q, kp, vp, jnp.asarray(pt), start, n_lp=MP)

        T = MP * page
        kg = kp[jnp.asarray(pt)].reshape(B, T, KV, D)
        vg = vp[jnp.asarray(pt)].reshape(B, T, KV, D)
        kb = jnp.broadcast_to(kg[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, KV * G, D)
        vb = jnp.broadcast_to(vg[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, KV * G, D)
        s = jnp.einsum("bchd,bthd->bhct", q, kb) * (D ** -0.5)
        k_pos = jnp.arange(T)[None, None, :]
        q_pos = (start[:, None] + jnp.arange(C)[None, :])[:, :, None]
        valid = k_pos <= q_pos  # (B, C, T)
        s = jnp.where(valid[:, None], s, -1e30)
        ref = jnp.einsum("bhct,bthe->bche", jax.nn.softmax(s, -1), vb)
        err = float(jnp.max(jnp.abs(o - ref)))
        assert err < 2e-5, err

    def test_pallas_backend_end_to_end_chunked(self):
        """attn_backend=pallas routes dense chunked prefill through the
        paged chunk kernel; greedy tokens must match the XLA gather path."""
        from dataclasses import replace

        cfg, params = _params_for("llama3.2-3b")
        toks = []
        for backend in ("xla", "pallas"):
            c = replace(cfg, attn_backend=backend)
            sched = Scheduler(
                c, params, ShardingCtx.null(),
                SchedulerConfig(n_slots=2, cache_len=64, page_size=16, chunk_budget=16),
            )
            for p in _prompts(cfg, [40, 12], seed=8):
                sched.submit(Request(p, max_new_tokens=4))
            toks.append([rs.tokens for rs in sched.run()])
        assert toks[0] == toks[1]


# ==========================================================================
# Pool accounting: incremental reservations
# ==========================================================================
class TestExtendTo:
    def test_extend_to_accounting(self):
        pool = PagePool(PageLayout(page_size=4, n_pages=6, span=24))
        pool.reserve(0, 0)
        assert pool.extend_to(0, 4)
        pool.grow_to(0, 4)
        pool.reserve(1, 0)
        assert pool.extend_to(1, 2)
        assert not pool.extend_to(1, 3), "only 2 pages left to back"
        assert pool.extend_to(1, 2) and pool.extend_to(1, 1), "shrink is a no-op"
        pool.release(0)
        assert pool.extend_to(1, 6)
        with pytest.raises(ValueError):
            pool.extend_to(3, 1)  # never reserved

    def test_extend_never_aliases(self):
        layout = PageLayout(page_size=2, n_pages=10, span=20)
        pool = PagePool(layout)
        held = {}
        for slot in range(3):
            pool.reserve(slot, 0)
            assert pool.extend_to(slot, 3)
            held[slot] = pool.grow_to(slot, 3)
        flat = [p for ids in held.values() for p in ids]
        assert len(flat) == len(set(flat)) == 9
        assert pool.available() == 1
