"""Serving engine: batched generation, sampling, cache growth, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.sharding.rules import ShardingCtx


@pytest.fixture(scope="module")
def dense_engine():
    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, B=2, P=8, seed=5):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size)}


class TestEngine:
    def test_greedy_generation_deterministic(self, dense_engine):
        cfg, params = dense_engine
        eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=6, cache_len=32))
        r1 = eng.generate(_prompt(cfg))
        r2 = eng.generate(_prompt(cfg))
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        assert r1.tokens.shape == (2, 6)
        assert (r1.tokens >= 0).all() and (r1.tokens < cfg.vocab_size).all()

    def test_temperature_sampling_in_vocab(self, dense_engine):
        cfg, params = dense_engine
        eng = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=4, cache_len=32, temperature=1.0, seed=3),
        )
        r = eng.generate(_prompt(cfg))
        assert (r.tokens < cfg.vocab_size).all()

    def test_stop_token_early_exit(self, dense_engine):
        cfg, params = dense_engine
        eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=8, cache_len=32))
        full = eng.generate(_prompt(cfg))
        stop = int(full.tokens[0, 1])
        eng2 = Engine(
            cfg, params, ShardingCtx.null(),
            ServeConfig(max_new_tokens=8, cache_len=32, stop_token=stop),
        )
        r = eng2.generate(_prompt(cfg))
        assert r.steps <= full.steps

    def test_recurrent_arch_generation(self):
        cfg = get_config("recurrentgemma-2b").reduced()
        params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=5, cache_len=64))
        r = eng.generate(_prompt(cfg))
        assert r.tokens.shape == (2, 5)

    def test_greedy_matches_decode_path(self, dense_engine):
        """Engine tokens == manual prefill+decode argmax chain."""
        cfg, params = dense_engine
        sctx = ShardingCtx.null()
        eng = Engine(cfg, params, sctx, ServeConfig(max_new_tokens=4, cache_len=32))
        batch = _prompt(cfg)
        r = eng.generate(batch)

        logits, states = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))(params, batch)
        states = eng._grow_states(states, batch["tokens"].shape[1], 2)
        toks = [np.asarray(jnp.argmax(logits[:, -1, : cfg.vocab_size], -1))]
        tok = jnp.asarray(toks[-1])[:, None].astype(jnp.int32)
        dec = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))
        for _ in range(3):
            lo, states = dec(params, states, tok)
            tok = jnp.argmax(lo[:, -1, : cfg.vocab_size], -1)[:, None].astype(jnp.int32)
            toks.append(np.asarray(tok)[:, 0])
        np.testing.assert_array_equal(r.tokens, np.stack(toks, 1))
