"""Property tests for the plan layer (serve/plan.py) against a real
MemoryManager (serve/memory.py).

Neither module imports JAX, so these properties run without compiling a
single program — the point of the layered split. Three families:

  * sizing: buckets are powers of two from fixed sets, pads never lose
    tokens, the planner's page demands never exceed what the
    MemoryManager's capacity queries said was available (no
    over-commit);
  * safety: decode plans never include a frozen slot, victim picks
    respect protection / shard locality / the younger-streamer rule;
  * determinism: plan -> execute -> plan over a fixed arrival trace is
    a pure function of the trace — two independent replays produce the
    same decision sequence and the same page-table state.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

from _compat import given, settings, st  # noqa: E402

from repro.serve import plan as planlib  # noqa: E402
from repro.serve.memory import MemoryManager  # noqa: E402
from repro.serve.pages import PageLayout  # noqa: E402


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _mem(page_size=8, n_pages=16, span=128, data_shards=1, n_slots=4):
    return MemoryManager(
        PageLayout(
            page_size=page_size, n_pages=n_pages, span=span,
            data_shards=data_shards,
        ),
        n_slots,
    )


# ==========================================================================
# Sizing
# ==========================================================================
class TestBucketLen:
    @given(
        token_len=st.integers(min_value=1, max_value=512),
        min_bucket=st.sampled_from([1, 4, 8, 16]),
        cache_len=st.sampled_from([64, 128, 256, 1024]),
    )
    @settings(max_examples=80)
    def test_bucketed_pad_never_loses_tokens(self, token_len, min_bucket, cache_len):
        if token_len > cache_len:
            return  # separate property below
        b = planlib.bucket_len(
            token_len, bucketed=True, min_bucket=min_bucket,
            cache_len=cache_len, prefix_len=0, long_ok=False,
        )
        assert b >= token_len
        assert b <= cache_len
        # Power of two unless clamped to the cache cap.
        assert _is_pow2(b) or b == cache_len

    @given(token_len=st.integers(min_value=1, max_value=512))
    @settings(max_examples=30)
    def test_unbucketed_is_identity(self, token_len):
        assert planlib.bucket_len(
            token_len, bucketed=False, min_bucket=8,
            cache_len=64, prefix_len=0, long_ok=False,
        ) == token_len

    @given(over=st.integers(min_value=1, max_value=100))
    @settings(max_examples=20)
    def test_past_cap_requires_long_ok(self, over):
        cache_len = 64
        try:
            planlib.bucket_len(
                cache_len + over, bucketed=True, min_bucket=8,
                cache_len=cache_len, prefix_len=0, long_ok=False,
            )
            raise AssertionError("expected RuntimeError past the cap")
        except RuntimeError:
            pass
        b = planlib.bucket_len(
            cache_len + over, bucketed=True, min_bucket=8,
            cache_len=cache_len, prefix_len=0, long_ok=True,
        )
        assert _is_pow2(b) and b >= cache_len + over


class TestChunkAndVerifySizing:
    @given(
        remaining=st.integers(min_value=1, max_value=400),
        chunk_budget=st.sampled_from([16, 32, 48, 100]),
        min_chunk=st.sampled_from([4, 8, 16]),
        start=st.integers(min_value=0, max_value=64),
    )
    @settings(max_examples=100)
    def test_chunk_plan_shapes_and_pages(self, remaining, chunk_budget, min_chunk, start):
        if min_chunk > chunk_budget:
            return
        mem = _mem(page_size=4, n_pages=64, n_slots=4)
        cp = planlib.plan_chunk(
            0, 0, start, remaining,
            chunk_budget=chunk_budget, min_chunk=min_chunk, mem=mem,
        )
        # Shapes come from the fixed pow2 set [min_chunk, pow2_floor(budget)].
        assert _is_pow2(cp.bucket)
        assert min_chunk <= cp.bucket <= planlib.pow2_floor(chunk_budget)
        assert 1 <= cp.n_real <= min(cp.bucket, remaining)
        # Page demand covers exactly the post-chunk prefix, no more.
        assert cp.need_pages == mem.pages_for_len(start + cp.n_real)
        assert _is_pow2(cp.n_lp) or cp.n_lp == mem.max_pages
        assert cp.n_lp >= max(cp.need_pages, 1)

    @given(
        k=st.integers(min_value=1, max_value=8),
        draft_k=st.integers(min_value=1, max_value=8),
        start=st.integers(min_value=0, max_value=100),
    )
    @settings(max_examples=60)
    def test_verify_plan_bucket_is_bounded(self, k, draft_k, start):
        if k > draft_k:
            return  # scheduler never drafts past draft_k
        mem = _mem(page_size=4, n_pages=64, n_slots=4)
        vp = planlib.plan_verify(0, 0, start, k, draft_k=draft_k, mem=mem)
        assert vp.n_real == k + 1
        assert _is_pow2(vp.bucket)
        assert vp.n_real <= vp.bucket <= planlib.pow2_ceil(draft_k + 1)
        assert vp.need_pages == mem.pages_for_len(start + k + 1)


# ==========================================================================
# No over-commit: plans vs MemoryManager capacity
# ==========================================================================
class TestNoOvercommit:
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        data_shards=st.sampled_from([1, 2]),
    )
    @settings(max_examples=40)
    def test_admission_plans_never_exceed_capacity(self, seed, data_shards):
        """Drive random reserve/grow/release traffic; whenever the planner
        says an admission fits, actually reserving and extending to the
        worst case must succeed — the capacity query is never optimistic."""
        import random

        rng = random.Random(seed)
        n_slots, n_pages = 4, 16
        mem = _mem(page_size=4, n_pages=n_pages, data_shards=data_shards,
                   n_slots=n_slots)
        live: set[int] = set()
        for _ in range(30):
            op = rng.random()
            free = [s for s in range(n_slots) if s not in live]
            if op < 0.5 and free:
                slot = rng.choice(free)
                n_worst = rng.randint(1, n_pages // data_shards)
                if planlib.can_admit_prefill(mem, slot, n_worst):
                    mem.reserve(slot, n_worst)
                    assert mem.extend_to(slot, n_worst), (
                        "planner said fit; pool disagreed"
                    )
                    mem.grow(slot, rng.randint(1, n_worst))
                    live.add(slot)
            elif op < 0.75 and live:
                slot = rng.choice(sorted(live))
                held = mem.held(slot)
                want = rng.randint(held, n_pages // data_shards)
                if planlib.can_resume_swap(mem, slot, want - held):
                    # available_for promised headroom: growth must land.
                    if mem.extend_to(slot, want):
                        mem.grow(slot, want)
            elif live:
                slot = rng.choice(sorted(live))
                mem.release(slot)
                live.discard(slot)
        # Conservation at drain.
        for slot in sorted(live):
            mem.release(slot)
        assert mem.in_use == 0
        assert mem.available_total() == n_pages

    @given(seed=st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=40)
    def test_streaming_chunks_never_overcommit(self, seed):
        """Stream a random prompt chunk-by-chunk: every plan's page demand
        is backable exactly when extend_to says so; the page table mirror
        only ever maps pages the pool granted."""
        import random

        rng = random.Random(seed)
        mem = _mem(page_size=4, n_pages=8, n_slots=2)
        prompt_len = rng.randint(1, 40)
        mem.reserve(0, 0)
        start = 0
        while start < prompt_len:
            cp = planlib.plan_chunk(
                0, 0, start, prompt_len - start,
                chunk_budget=16, min_chunk=4, mem=mem,
            )
            if not mem.extend_to(0, cp.need_pages):
                break  # executor would defer/preempt here
            mem.grow(0, cp.need_pages)
            assert mem.held(0) == cp.need_pages
            mapped = [p for p in mem.pt[0] if p != mem.trash_of(0)]
            assert len(mapped) == cp.need_pages
            assert len(set(mapped)) == cp.need_pages  # no aliasing
            start += cp.n_real
        mem.release(0)
        assert mem.in_use == 0


# ==========================================================================
# Frozen slots and victim picks
# ==========================================================================
class TestDecodeRowsAndVictims:
    @given(
        mask=st.lists(st.booleans(), min_size=1, max_size=12),
        handled=st.lists(st.integers(min_value=0, max_value=11), max_size=6),
    )
    @settings(max_examples=60)
    def test_decode_rows_exclude_frozen_and_handled(self, mask, handled):
        rows = planlib.decode_rows(mask, handled)
        assert rows == tuple(sorted(rows))
        for r in rows:
            assert mask[r] and r not in set(handled)
        for i, a in enumerate(mask):
            if a and i not in set(handled):
                assert i in rows

    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        n=st.integers(min_value=1, max_value=8),
        shard=st.sampled_from([None, 0, 1]),
    )
    @settings(max_examples=60)
    def test_pick_victim_safety(self, seed, n, shard):
        import random

        rng = random.Random(seed)
        views = [
            planlib.SlotView(
                slot=i, rid=rng.randint(0, 20),
                status=rng.choice(["active", "prefilling"]),
                t_admit=rng.random(), preemptable=rng.random() < 0.7,
                shard=rng.randint(0, 1),
            )
            for i in range(n)
        ]
        protect = rng.randrange(n)
        requester = rng.randint(0, 20)
        v = planlib.pick_victim(
            views, protect=protect, requester_rid=requester, shard=shard,
        )
        if v is None:
            return
        assert v != protect
        view = next(x for x in views if x.slot == v)
        if shard is not None:
            assert view.shard == shard
        eligible = [
            x for x in views
            if x.slot != protect and (shard is None or x.shard == shard)
        ]
        actives = [x for x in eligible if x.status == "active" and x.preemptable]
        if actives:
            # LRU among preemptable actives.
            assert view.status == "active" and view.preemptable
            assert view.t_admit == min(x.t_admit for x in actives)
        else:
            # Younger-streamer rule: only a streamer younger than the
            # requester, and the youngest of them.
            assert view.status == "prefilling"
            assert view.rid > requester
            assert view.rid == max(
                x.rid for x in eligible
                if x.status == "prefilling" and x.rid > requester
            )


# ==========================================================================
# Determinism: plan -> execute -> plan over a fixed arrival trace
# ==========================================================================
class TestDeterminism:
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        data_shards=st.sampled_from([1, 2]),
    )
    @settings(max_examples=25)
    def test_trace_replay_is_bit_identical(self, seed, data_shards):
        """Replay the same arrival trace through two independent
        plan+memory stacks: every decision and the final page-table state
        must match exactly (the plan layer has no hidden state)."""
        import random

        def run_trace():
            rng = random.Random(seed)
            mem = _mem(page_size=4, n_pages=16, data_shards=data_shards,
                       n_slots=4)
            decisions = []
            streams: dict[int, tuple[int, int]] = {}  # slot -> (start, len)
            rid = 0
            for _ in range(40):
                op = rng.random()
                free = [s for s in range(4) if s not in streams]
                if op < 0.4 and free:
                    slot = free[0]
                    plen = rng.randint(1, 30)
                    n_worst = mem.pages_for_len(plen + 8)
                    ok = planlib.can_admit_streaming(
                        mem, slot, n_worst, reservation_free=True
                    )
                    decisions.append(("admit", slot, n_worst, ok))
                    if ok:
                        mem.reserve(slot, 0)
                        streams[slot] = (0, plen)
                        rid += 1
                elif streams:
                    slot = sorted(streams)[0]
                    start, plen = streams[slot]
                    cp = planlib.plan_chunk(
                        slot, rid, start, plen - start,
                        chunk_budget=16, min_chunk=4, mem=mem,
                    )
                    decisions.append(("chunk", cp))
                    if mem.extend_to(slot, cp.need_pages):
                        mem.grow(slot, cp.need_pages)
                        start += cp.n_real
                        if start >= plen:
                            mem.release(slot)
                            del streams[slot]
                        else:
                            streams[slot] = (start, plen)
                    else:
                        mem.release(slot)
                        del streams[slot]
            return decisions, mem.pt.tolist(), mem.in_use

        a, b = run_trace(), run_trace()
        assert a == b
