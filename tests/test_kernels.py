"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
and property tests on chunking invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.moe_gmm import pad_group_sizes_to_blocks

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if jnp.dtype(dtype) == jnp.bfloat16 else TOL[jnp.float32]


def _fold(x):
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _bcast_kv(k, H):
    B, S, KV, D = k.shape
    G = H // KV
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, D)).reshape(B, S, H, D)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,D,blk,causal,window",
        [
            (2, 128, 4, 4, 64, 64, True, 0),  # MHA causal
            (1, 256, 8, 2, 64, 64, True, 0),  # GQA causal
            (2, 128, 4, 1, 32, 32, True, 0),  # MQA
            (1, 128, 2, 2, 64, 64, False, 0),  # bidirectional
            (1, 256, 2, 2, 64, 64, True, 64),  # sliding window
            (1, 192, 2, 2, 128, 64, True, 0),  # non-pow2 seq, d=128
        ],
    )
    def test_fwd_vs_ref(self, dtype, B, S, H, KV, D, blk, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
        v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
        o = ops.flash_attention(q, k, v, causal=causal, window=window, blk_q=blk, blk_k=blk)
        oref = ref.sdpa_ref(
            _fold(q), _fold(_bcast_kv(k, H)), _fold(_bcast_kv(v, H)),
            causal=causal, window=window,
        ).reshape(B, H, S, D).transpose(0, 2, 1, 3)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - oref.astype(jnp.float32))))
        assert err < _tol(dtype), err

    def test_bwd_vs_autodiff_ref(self):
        B, S, H, KV, D = 1, 128, 2, 1, 32
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KV, D))
        v = jax.random.normal(ks[2], (B, S, KV, D))

        def loss_k(q, k, v):
            return jnp.sum(ops.flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64) ** 2)

        def loss_r(q, k, v):
            o = ref.sdpa_ref(
                _fold(q), _fold(_bcast_kv(k, H)), _fold(_bcast_kv(v, H)), causal=True
            )
            return jnp.sum(o ** 2)

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
        for a, b, name in zip(gk, gr, "qkv"):
            scale = float(jnp.max(jnp.abs(b))) + 1e-6
            err = float(jnp.max(jnp.abs(a - b))) / scale
            assert err < 1e-4, f"d{name} rel err {err}"

    def test_block_size_invariance(self):
        B, S, H, D = 1, 256, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, H, D))
        v = jax.random.normal(ks[2], (B, S, H, D))
        o1 = ops.flash_attention(q, k, v, blk_q=64, blk_k=64)
        o2 = ops.flash_attention(q, k, v, blk_q=128, blk_k=32)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("window", [0, 64])
    def test_vs_ref(self, dtype, window):
        B, H, KV, D, T = 2, 4, 2, 64, 256
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
        kc = jax.random.normal(ks[1], (B, T, KV, D), dtype)
        vc = jax.random.normal(ks[2], (B, T, KV, D), dtype)
        k_pos = jnp.arange(T)
        cur = jnp.asarray(137)
        o = ops.decode_attention_op(q, kc, vc, k_pos, cur, window=window, blk_k=64)
        G = H // KV
        qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
        kf = kc.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
        vf = vc.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
        oref = ref.decode_attention_ref(qf, kf, vf, k_pos, cur, window=window)
        oref = oref.reshape(B, KV, G, D).reshape(B, 1, H, D)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - oref.astype(jnp.float32))))
        assert err < _tol(dtype), err

    def test_ring_positions_mask_unwritten(self):
        """Negative k_pos (never-written ring slots) must not contribute."""
        B, H, KV, D, T = 1, 2, 1, 32, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kc = jax.random.normal(ks[1], (B, T, KV, D))
        vc = jax.random.normal(ks[2], (B, T, KV, D))
        k_pos = jnp.where(jnp.arange(T) < 10, jnp.arange(T), -1)
        cur = jnp.asarray(9)
        o = ops.decode_attention_op(q, kc, vc, k_pos, cur, blk_k=32)
        # corrupting masked slots must not change the output
        kc2 = kc.at[:, 10:].set(1e3)
        o2 = ops.decode_attention_op(q, kc2, vc, k_pos, cur, blk_k=32)
        assert float(jnp.max(jnp.abs(o - o2))) == 0.0


class TestRGLRU:
    @pytest.mark.parametrize("B,T,D,bt,bd", [(2, 128, 256, 32, 128), (1, 64, 128, 64, 64)])
    def test_vs_ref(self, B, T, D, bt, bd):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D)))
        b = jax.random.normal(ks[1], (B, T, D)) * 0.1
        h = ops.rglru_op(a, b, blk_t=bt, blk_d=bd)
        hr = ref.rglru_ref(a, b)
        assert float(jnp.max(jnp.abs(h - hr))) < 1e-5

    def test_initial_state(self):
        B, T, D = 1, 32, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, D)))
        b = jax.random.normal(ks[1], (B, T, D))
        h0 = jax.random.normal(ks[2], (B, D))
        h = ops.rglru_op(a, b, h0, blk_t=16, blk_d=64)
        hr = ref.rglru_ref(a, b, h0)
        assert float(jnp.max(jnp.abs(h - hr))) < 1e-5

    @given(
        t=st.sampled_from([16, 32, 64]),
        bt=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_block_invariance(self, t, bt, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, t, 128)))
        b = jax.random.normal(ks[1], (1, t, 128))
        h = ops.rglru_op(a, b, blk_t=min(bt, t), blk_d=128)
        hr = ref.rglru_ref(a, b)
        assert float(jnp.max(jnp.abs(h - hr))) < 1e-5


class TestMLSTM:
    @pytest.mark.parametrize("chunk", [16, 32, 64])
    def test_vs_sequential_ref(self, chunk):
        B, S, nh, dh = 2, 64, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        sc = dh ** -0.5
        q = jax.random.normal(ks[0], (B, S, nh, dh)) * 0.3
        k = jax.random.normal(ks[1], (B, S, nh, dh)) * 0.3
        v = jax.random.normal(ks[2], (B, S, nh, dh))
        i_pre = jax.random.normal(ks[3], (B, S, nh))
        f_pre = jax.random.normal(ks[4], (B, S, nh)) + 2.0
        h = ops.mlstm_op(q * sc, k * sc, v, i_pre, f_pre, chunk=chunk)
        fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * nh, S, dh)
        foldg = lambda x: x.transpose(0, 2, 1).reshape(B * nh, S)
        hr = ref.mlstm_ref(fold(q * sc), fold(k * sc), fold(v), foldg(i_pre), foldg(f_pre))
        hr = hr.reshape(B, nh, S, dh).transpose(0, 2, 1, 3)
        err = float(jnp.max(jnp.abs(h - hr)))
        assert err < 1e-5, err

    def test_jnp_chunked_matches_sequential(self):
        """models.recurrent.mlstm_chunked (the XLA path) vs step oracle."""
        from repro.models.recurrent import mlstm_chunked, mlstm_sequential

        B, S, nh, dh = 1, 96, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        args = (
            jax.random.normal(ks[0], (B, S, nh, dh)) * 0.3,
            jax.random.normal(ks[1], (B, S, nh, dh)) * 0.3,
            jax.random.normal(ks[2], (B, S, nh, dh)),
            jax.random.normal(ks[3], (B, S, nh)),
            jax.random.normal(ks[4], (B, S, nh)) + 1.0,
        )
        h1, _ = mlstm_chunked(*args, chunk=32)
        h2, _ = mlstm_sequential(*args)
        assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5


class TestMoEGMM:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, dtype):
        M, K, N, G, blk = 256, 64, 96, 3, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        lhs = jax.random.normal(ks[0], (M, K), dtype)
        rhs = jax.random.normal(ks[1], (G, K, N), dtype)
        gs = jnp.array([64, 128, 64], jnp.int32)
        out = ops.moe_gmm_op(lhs, rhs, gs, blk_m=blk, blk_n=32)
        gm = pad_group_sizes_to_blocks(gs, blk, M)
        outr = ref.gmm_ref(lhs, rhs, np.asarray(gm), blk)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - outr.astype(jnp.float32))))
        assert err < _tol(dtype), err

    def test_group_map_helper(self):
        gs = jnp.array([128, 0, 256], jnp.int32)
        gm = pad_group_sizes_to_blocks(gs, 128, 384)
        assert list(np.asarray(gm)) == [0, 2, 2]
