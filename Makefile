.PHONY: test bench bench-smoke demo

# Tier-1 verify (ROADMAP.md): must stay green.
test:
	./scripts/test.sh

bench:
	PYTHONPATH=src python benchmarks/run.py

# B1-B5 at tiny sizes: the CI end-to-end exercise of the experiment layer.
bench-smoke:
	PYTHONPATH=src python benchmarks/run.py --smoke

demo:
	PYTHONPATH=src python examples/serve_demo.py
