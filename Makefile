.PHONY: test bench demo

# Tier-1 verify (ROADMAP.md): must stay green.
test:
	./scripts/test.sh

bench:
	PYTHONPATH=src python benchmarks/run.py

demo:
	PYTHONPATH=src python examples/serve_demo.py
